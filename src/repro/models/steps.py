"""train_step / serve_step — the functions the launcher lowers and compiles.

``train_step`` is a full AdamW step (fwd + bwd + clip + update) with optional
int8 gradient compression on the DP all-reduce path.  ``serve_step`` is one
decode step against a KV/state cache (``decode_*``/``long_*`` shapes lower
this, not train_step).  ``prefill_step`` builds the cache for a prompt.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..optim import (AdamWConfig, adamw_init, adamw_update,
                     compress_gradients, decompress_gradients)
from ..sharding import with_logical_constraint as wlc
from .config import ModelConfig
from .stack import decode_step as _decode
from .stack import forward_train, init_params, prefill

MTP_WEIGHT = 0.1


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is not None:
        return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.mean(ll)


def loss_fn(params, cfg: ModelConfig, batch) -> Tuple[jnp.ndarray, Dict]:
    logits, aux, mtp_logits = forward_train(params, cfg, batch)
    tokens = batch["tokens"]
    S_tok = tokens.shape[1]
    # frontends prepend a prefix; loss applies to the token region only
    logits_tok = logits[:, -S_tok:, :]
    loss = cross_entropy(logits_tok[:, :-1], tokens[:, 1:])
    metrics = {"ce": loss, "aux": aux}
    loss = loss + aux
    if mtp_logits is not None:
        mtp_tok = mtp_logits[:, -S_tok:, :]
        # MTP depth-1 predicts token t+2 from position t
        mtp_loss = cross_entropy(mtp_tok[:, :-2], tokens[:, 2:])
        metrics["mtp"] = mtp_loss
        loss = loss + MTP_WEIGHT * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    compress: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "err"?}.  Gradient compression (int8 + error
    feedback) applies between backward and the optimizer; under pjit the DP
    all-reduce then moves int8 wire data (8× collective-term reduction).
    """

    def train_step(state, batch):
        params = state["params"]
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        if compress:
            compressed, err = compress_gradients(grads, state.get("err"))
            grads = decompress_gradients(compressed)
            state = dict(state, err=err)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], opt_cfg)
        metrics.update(opt_metrics)
        return dict(state, params=new_params, opt=new_opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key,
                     compress: bool = False) -> Tuple[Dict, Dict]:
    """Returns (state, axes) — axes mirror state for sharding-spec building."""
    params, axes = init_params(cfg, key)
    state = {"params": params, "opt": adamw_init(params, opt_cfg)}
    state_axes = {"params": axes,
                  "opt": {"mu": axes, "nu": axes, "step": ()}}
    if compress:
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        state_axes["err"] = axes
    return state, state_axes


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, token, caches, index) -> (logits, caches)."""

    def serve_step(params, token, caches, index):
        return _decode(params, cfg, token, caches, index)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch)

    return prefill_step


# ---------------------------------------------------------------------------
# decode-cache specs (for the dry-run: allocate caches at target length)
# ---------------------------------------------------------------------------


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int,
                       dtype=None) -> Tuple[Any, Any]:
    """Build zeroed caches (and their logical axes) for decode at seq_len."""
    dtype = dtype or cfg.activation_dtype
    segs = cfg.segments()
    caches = {"index": jnp.zeros((), jnp.int32), "segments": []}
    axes = {"index": (), "segments": []}

    def attn_cache(stacked: Optional[int]):
        lead = (stacked,) if stacked else ()
        lax = (None,) if stacked else ()
        if cfg.attention == "mla":
            c = {"c_kv": jnp.zeros(lead + (batch, seq_len, cfg.kv_lora_rank),
                                   dtype),
                 "k_rope": jnp.zeros(lead + (batch, seq_len,
                                             cfg.rope_head_dim), dtype)}
            a = {"c_kv": lax + ("batch", "cache_seq", None),
                 "k_rope": lax + ("batch", "cache_seq", None)}
        else:
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            c = {"k": jnp.zeros(lead + (batch, seq_len, kv, hd), dtype),
                 "v": jnp.zeros(lead + (batch, seq_len, kv, hd), dtype)}
            a = {"k": lax + ("batch", "cache_seq", "kv_heads", "head_dim"),
                 "v": lax + ("batch", "cache_seq", "kv_heads", "head_dim")}
        return c, a

    def mamba_cache(stacked: Optional[int]):
        from .ssm import _dims
        d_in, H, P, N = _dims(cfg)
        K = cfg.ssm.conv_width
        lead = (stacked,) if stacked else ()
        lax = (None,) if stacked else ()
        c = {"conv": jnp.zeros(lead + (batch, K - 1, d_in + 2 * N), dtype),
             "state": jnp.zeros(lead + (batch, H, P, N), jnp.float32)}
        a = {"conv": lax + ("batch", None, "heads"),
             "state": lax + ("batch", "heads", None, "states")}
        return c, a

    def rwkv_cache(stacked: Optional[int]):
        H, N = cfg.d_model // 64, 64
        lead = (stacked,) if stacked else ()
        lax = (None,) if stacked else ()
        c = {"mixer": {"x_prev": jnp.zeros(lead + (batch, 1, cfg.d_model),
                                           dtype),
                       "state": jnp.zeros(lead + (batch, H, N, N),
                                          jnp.float32)},
             "cmix_x_prev": jnp.zeros(lead + (batch, 1, cfg.d_model), dtype)}
        a = {"mixer": {"x_prev": lax + ("batch", None, None),
                       "state": lax + ("batch", "heads", None, "states")},
             "cmix_x_prev": lax + ("batch", None, None)}
        return c, a

    for kind, is_moe, count in segs:
        stacked = None if kind == "shared_attn" else count
        if kind in ("attn", "shared_attn"):
            c, a = attn_cache(stacked)
            entry, entry_ax = {"mixer": c}, {"mixer": a}
            if cfg.cross_attention:
                kvh = {"k": jnp.zeros(
                    ((stacked,) if stacked else ()) +
                    (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim),
                    dtype)}
                kvh["v"] = kvh["k"]
                entry["cross_kv"] = kvh
                lax = (None,) if stacked else ()
                entry_ax["cross_kv"] = {
                    "k": lax + ("batch", None, "kv_heads", "head_dim"),
                    "v": lax + ("batch", None, "kv_heads", "head_dim")}
        elif kind == "mamba2":
            c, a = mamba_cache(stacked)
            entry, entry_ax = {"mixer": c}, {"mixer": a}
        elif kind == "rwkv6":
            entry, entry_ax = rwkv_cache(stacked)
        else:
            raise ValueError(kind)
        caches["segments"].append(entry)
        axes["segments"].append(entry_ax)
    return caches, axes
