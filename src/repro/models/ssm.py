"""Mamba2 (SSD — state space duality) token mixer.

Implements the chunked SSD algorithm of Mamba2: within a chunk the recurrence
is computed with a (Q, Q) lower-triangular decay matrix (MXU work); chunk
boundary states propagate with a lax.scan.  Exactly equivalent to the
per-token recurrence (tested against ``ssd_reference``).

Recurrence (per head; p = head dim, n = state dim):

    h_t = exp(a_t) h_{t-1} + dt_t · (B_t ⊗ x_t)        a_t = -exp(A_log)·dt_t
    y_t = C_t · h_t + D ⊙ x_t

Decode carries ``(conv_cache (B, conv-1, d_conv_in), state (B, H, p, n))``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding import with_logical_constraint as wlc
from .config import ModelConfig
from .layers import Params, dense, dense_init, rmsnorm, rmsnorm_init


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = s.num_heads or d_in // s.head_dim
    return d_in, nheads, s.head_dim, s.state_dim


def mamba2_init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, P, N = _dims(cfg)
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 6)
    p: Params = {}
    a: Params = {}
    # in_proj → [z (d_in), xBC (d_in + 2N), dt (H)]
    p["in_proj"], a["in_proj"] = dense_init(
        ks[0], d, 2 * d_in + 2 * N + H, None, "heads", dtype)
    p["conv_w"] = (jax.random.normal(ks[1], (s.conv_width, conv_dim),
                                     jnp.float32) / s.conv_width).astype(dtype)
    a["conv_w"] = ("conv", "heads")
    p["conv_b"] = jnp.zeros((conv_dim,), dtype)
    a["conv_b"] = ("heads",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32)
    a["A_log"] = ("heads",)
    p["D"] = jnp.ones((H,), jnp.float32)
    a["D"] = ("heads",)
    p["dt_bias"] = jnp.zeros((H,), jnp.float32)
    a["dt_bias"] = ("heads",)
    p["norm"], a["norm"] = rmsnorm_init(d_in, dtype)
    p["out_proj"], a["out_proj"] = dense_init(ks[2], d_in, d, "heads", None,
                                              dtype)
    return p, a


def _split_proj(cfg, proj):
    d_in, H, P, N = _dims(cfg)
    z = proj[..., :d_in]
    xBC = proj[..., d_in:2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def _causal_conv(cfg, xBC, conv_w, conv_b, cache=None):
    """Depthwise causal conv (width K) via explicit shifts.

    xBC (B, S, Cd); cache (B, K-1, Cd) holds the previous K-1 inputs.
    Returns (out, new_cache).
    """
    K = cfg.ssm.conv_width
    B, S, Cd = xBC.shape
    if cache is None:
        cache = jnp.zeros((B, K - 1, Cd), xBC.dtype)
    ext = jnp.concatenate([cache, xBC], axis=1)          # (B, S+K-1, Cd)
    out = jnp.zeros_like(xBC)
    for i in range(K):  # static unroll; K = 4
        out = out + ext[:, i:i + S, :] * conv_w[i][None, None, :]
    out = jax.nn.silu(out + conv_b[None, None, :])
    new_cache = ext[:, -(K - 1):, :]   # last K-1 raw inputs
    return out, new_cache


def ssd_reference(cfg: ModelConfig, xh, dt, Bm, Cm, A_log, D, state=None):
    """Per-token recurrence oracle (slow; tests only).

    xh (B,S,H,P) | dt (B,S,H) | Bm,Cm (B,S,N) | state (B,H,P,N)
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    A = -jnp.exp(A_log)                                   # (H,)
    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(A[None, :] * dt_t)                # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    xs = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1) + xh.astype(jnp.float32) * D[None, None, :, None]
    return y, state


def ssd_chunked(cfg: ModelConfig, xh, dt, Bm, Cm, A_log, D, state=None):
    """Chunked SSD — same I/O contract as :func:`ssd_reference`."""
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = cfg.ssm.chunk
    if S % Q != 0:
        Q = S  # degenerate single chunk (smoke tests with tiny seq)
    nC = S // Q
    A = -jnp.exp(A_log)

    xh = xh.astype(jnp.float32).reshape(B, nC, Q, H, P)
    dtc = dt.reshape(B, nC, Q, H)
    Bc = Bm.astype(jnp.float32).reshape(B, nC, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(B, nC, Q, N)

    a = A[None, None, None, :] * dtc                       # (B,nC,Q,H) ≤ 0
    cum = jnp.cumsum(a, axis=2)                            # inclusive
    # intra-chunk: M[t,s] = C_t·B_s · exp(cum_t - cum_s) · dt_s   (s ≤ t)
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)             # (B,nC,Q,Q)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nC,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: diff > 0 above the diagonal would overflow and poison
    # the gradient of the untaken where-branch (NaN via inf·0)
    diff = jnp.where(tri[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff)
    M = cb[..., None] * decay * dtc[:, :, None, :, :]      # (B,nC,Q,Q,H)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", M, xh)

    # chunk summary state: S_c = Σ_s exp(cum_Q - cum_s) dt_s B_s ⊗ x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,nC,Q,H)
    Ssum = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                      tail * dtc, Bc, xh)                  # (B,nC,H,P,N)
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))              # (B,nC,H)

    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)

    def boundary(h, inp):
        s_c, dec = inp                                     # (B,H,P,N), (B,H)
        h_next = h * dec[..., None, None] + s_c
        return h_next, h                                   # emit state BEFORE chunk

    final_state, hs = jax.lax.scan(
        boundary, state,
        (jnp.moveaxis(Ssum, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(hs, 0, 1)                        # (B,nC,H,P,N)

    # inter-chunk: y_t += C_t · (exp(cum_t) h_prev)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + xh.reshape(B, S, H, P) * D[None, None, :, None]
    return y, final_state


def mamba2_train(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    d_in, H, P, N = _dims(cfg)
    proj = dense(p["in_proj"], x)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC, _ = _causal_conv(cfg, xBC, p["conv_w"].astype(x.dtype),
                          p["conv_b"].astype(x.dtype))
    xh = xBC[..., :d_in].reshape(*x.shape[:2], H, P)
    Bm = xBC[..., d_in:d_in + N]
    Cm = xBC[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    y, _ = ssd_chunked(cfg, xh, dt, Bm, Cm, p["A_log"], p["D"])
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y)


def mamba2_prefill(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    d_in, H, P, N = _dims(cfg)
    proj = dense(p["in_proj"], x)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC_c, conv_cache = _causal_conv(cfg, xBC, p["conv_w"].astype(x.dtype),
                                     p["conv_b"].astype(x.dtype))
    xh = xBC_c[..., :d_in].reshape(*x.shape[:2], H, P)
    Bm = xBC_c[..., d_in:d_in + N]
    Cm = xBC_c[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    y, state = ssd_chunked(cfg, xh, dt, Bm, Cm, p["A_log"], p["D"])
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y), {"conv": conv_cache, "state": state}


def mamba2_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray, cache, index):
    """Single-token state update.  x: (B, 1, d)."""
    d_in, H, P, N = _dims(cfg)
    B = x.shape[0]
    proj = dense(p["in_proj"], x)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC, conv_cache = _causal_conv(cfg, xBC, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype),
                                   cache=cache["conv"])
    xh = xBC[..., :d_in].reshape(B, 1, H, P)
    Bm = xBC[..., d_in:d_in + N]
    Cm = xBC[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    y, state = ssd_reference(cfg, xh, dt, Bm, Cm, p["A_log"], p["D"],
                             state=cache["state"])
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y), {"conv": conv_cache, "state": state}
