"""The composable decoder (and encoder-decoder) stack.

One code path serves all ten assigned architectures, driven by ModelConfig:
layers are grouped into *segments* of identical (mixer kind, is_moe) so each
segment scans over stacked parameters (compile-time O(#segments), not
O(#layers)); Zamba2's shared attention block has a single parameter set
invoked at many depths; Whisper adds a bidirectional encoder + cross
attention; stub frontends prepend precomputed embeddings.

Public API:
    init_params(cfg, key)                       -> (params, axes)
    forward_train(params, cfg, batch)           -> (logits, aux_loss)
    prefill(params, cfg, batch)                 -> (logits, caches)
    decode_step(params, cfg, token, caches, i)  -> (logits, caches)
    input_specs(cfg, shape)                     -> ShapeDtypeStructs (launch/)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import with_logical_constraint as wlc
from . import attention as attn
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .config import ATTN, MAMBA2, RWKV6, SHARED_ATTN, ModelConfig
from .layers import (Params, dense, dense_init, embed, embed_init, mlp,
                     mlp_init, rmsnorm, rmsnorm_init, unembed)


# ---------------------------------------------------------------------------
# axes helpers (axes trees mirror params trees; leaves are tuples of names)
# ---------------------------------------------------------------------------

def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def prefix_axes(axes, prefix=None):
    """Prepend a logical axis (the stacked-layer dim) to every axes leaf."""
    if _is_axes_leaf(axes):
        return (prefix,) + axes
    if isinstance(axes, dict):
        return {k: prefix_axes(v, prefix) for k, v in axes.items()}
    if isinstance(axes, (list, tuple)):
        return type(axes)(prefix_axes(v, prefix) for v in axes)
    raise TypeError(f"bad axes node {axes!r}")


# ---------------------------------------------------------------------------
# per-layer block
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, kind: str, is_moe: bool, dtype,
                cross: bool = False):
    ks = jax.random.split(key, 6)
    p: Params = {}
    a: Params = {}
    p["ln1"], a["ln1"] = rmsnorm_init(cfg.d_model, dtype)
    if kind in (ATTN, SHARED_ATTN):
        if cfg.attention == "mla":
            p["mixer"], a["mixer"] = attn.mla_init(ks[0], cfg, dtype)
        else:
            p["mixer"], a["mixer"] = attn.gqa_init(ks[0], cfg, dtype)
    elif kind == MAMBA2:
        p["mixer"], a["mixer"] = ssm_mod.mamba2_init(ks[0], cfg, dtype)
    elif kind == RWKV6:
        p["mixer"], a["mixer"] = rwkv_mod.rwkv6_init(ks[0], cfg, dtype)
    if cross:
        p["ln_cross"], a["ln_cross"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"], a["cross"] = attn.gqa_init(ks[1], cfg, dtype)
    # MLP slot: attention blocks get a dense MLP or MoE; mamba blocks are
    # mixer-only; rwkv blocks use the squared-relu channel mix.
    if kind in (ATTN, SHARED_ATTN):
        p["ln2"], a["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        if is_moe:
            p["moe"], a["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
        else:
            p["mlp"], a["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                          cfg.mlp, dtype)
    elif kind == RWKV6:
        p["ln2"], a["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["cmix_k"], a["cmix_k"] = dense_init(ks[3], cfg.d_model, cfg.d_ff,
                                              None, "ffn", dtype)
        p["cmix_v"], a["cmix_v"] = dense_init(ks[4], cfg.d_ff, cfg.d_model,
                                              "ffn", None, dtype)
        p["cmix_r"], a["cmix_r"] = dense_init(ks[5], cfg.d_model, cfg.d_model,
                                              None, None, dtype)
        p["mu_ck"] = jnp.full((cfg.d_model,), 0.5, dtype)
        a["mu_ck"] = (None,)
        p["mu_cr"] = jnp.full((cfg.d_model,), 0.5, dtype)
        a["mu_cr"] = (None,)
    return p, a


def _channel_mix(p, cfg, x, x_prev):
    """RWKV squared-relu channel mix with token shift."""
    shifted = rwkv_mod._shift(x, x_prev)
    mk = p["mu_ck"].astype(x.dtype)[None, None, :]
    mr = p["mu_cr"].astype(x.dtype)[None, None, :]
    xk = x * (1 - mk) + shifted * mk
    xr = x * (1 - mr) + shifted * mr
    k = jnp.square(jax.nn.relu(dense(p["cmix_k"], xk)))
    return jax.nn.sigmoid(dense(p["cmix_r"], xr)) * dense(p["cmix_v"], k)


def _block_train(p, cfg: ModelConfig, kind: str, is_moe: bool, x,
                 enc_out=None, causal: bool = True):
    """Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in (ATTN, SHARED_ATTN):
        if cfg.attention == "mla":
            mix = attn.mla_train(p["mixer"], cfg, h)
        else:
            mix = attn.gqa_train(p["mixer"], cfg, h, causal=causal)
    elif kind == MAMBA2:
        mix = ssm_mod.mamba2_train(p["mixer"], cfg, h)
    elif kind == RWKV6:
        mix = rwkv_mod.rwkv6_train(p["mixer"], cfg, h)
    else:
        raise ValueError(kind)
    x = x + mix
    if "cross" in p and enc_out is not None:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        enc_kv = attn.cross_kv(p["cross"], cfg, enc_out)
        x = x + attn.gqa_cross(p["cross"], cfg, h, enc_kv)
    if "moe" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y, aux = moe_mod.moe_apply(p["moe"], cfg, h)
        x = x + y
    elif "mlp" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp)
    elif kind == RWKV6:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        B, _, d = x.shape
        x = x + _channel_mix(p, cfg, h, jnp.zeros((B, 1, d), x.dtype))
    x = wlc(x, ("batch", "seq", "d_model"))
    return x, aux


def _block_prefill(p, cfg, kind, is_moe, x, enc_out=None):
    """Returns (x, aux, cache)."""
    cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in (ATTN, SHARED_ATTN):
        if cfg.attention == "mla":
            mix, c = attn.mla_prefill(p["mixer"], cfg, h)
        else:
            mix, c = attn.gqa_prefill(p["mixer"], cfg, h)
        cache["mixer"] = c
    elif kind == MAMBA2:
        mix, c = ssm_mod.mamba2_prefill(p["mixer"], cfg, h)
        cache["mixer"] = c
    elif kind == RWKV6:
        mix, c = rwkv_mod.rwkv6_prefill(p["mixer"], cfg, h)
        cache["mixer"] = c
    x = x + mix
    if "cross" in p and enc_out is not None:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        enc_kv = attn.cross_kv(p["cross"], cfg, enc_out)
        cache["cross_kv"] = enc_kv
        x = x + attn.gqa_cross(p["cross"], cfg, h, enc_kv)
    if "moe" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y, aux = moe_mod.moe_apply(p["moe"], cfg, h)
        x = x + y
    elif "mlp" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp)
    elif kind == RWKV6:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        B, _, d = x.shape
        x = x + _channel_mix(p, cfg, h, jnp.zeros((B, 1, d), x.dtype))
        cache["cmix_x_prev"] = h[:, -1:, :]
    return x, aux, cache


def _block_decode(p, cfg, kind, is_moe, x, cache, index):
    """x: (B, 1, d).  Returns (x, cache)."""
    new_cache = dict(cache)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in (ATTN, SHARED_ATTN):
        if cfg.attention == "mla":
            mix, c = attn.mla_decode(p["mixer"], cfg, h, cache["mixer"], index)
        else:
            mix, c = attn.gqa_decode(p["mixer"], cfg, h, cache["mixer"], index)
        new_cache["mixer"] = c
    elif kind == MAMBA2:
        mix, c = ssm_mod.mamba2_decode(p["mixer"], cfg, h, cache["mixer"],
                                       index)
        new_cache["mixer"] = c
    elif kind == RWKV6:
        mix, c = rwkv_mod.rwkv6_decode(p["mixer"], cfg, h, cache["mixer"],
                                       index)
        new_cache["mixer"] = c
    x = x + mix
    if "cross" in p and "cross_kv" in cache:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn.gqa_cross(p["cross"], cfg, h, cache["cross_kv"])
    if "moe" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        y, _ = moe_mod.moe_apply(p["moe"], cfg, h)
        x = x + y
    elif "mlp" in p:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, cfg.mlp)
    elif kind == RWKV6:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + _channel_mix(p, cfg, h, cache["cmix_x_prev"])
        new_cache["cmix_x_prev"] = h
    return x, new_cache


# ---------------------------------------------------------------------------
# stack init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 16)
    p: Params = {}
    a: Params = {}
    p["embed"], a["embed"] = embed_init(keys[0], cfg.padded_vocab,
                                        cfg.d_model, dtype)
    segs = cfg.segments()
    seg_params: List[Any] = []
    seg_axes: List[Any] = []
    seg_keys = jax.random.split(keys[1], len(segs))
    for si, (kind, is_moe, count) in enumerate(segs):
        if kind == SHARED_ATTN:
            seg_params.append({})   # weights live in p["shared_block"]
            seg_axes.append({})
            continue
        lkeys = jax.random.split(seg_keys[si], count)
        _, ax = _block_init(lkeys[0], cfg, kind, is_moe, dtype,
                            cross=cfg.cross_attention)
        stacked = jax.vmap(
            lambda k: _block_init(k, cfg, kind, is_moe, dtype,
                                  cross=cfg.cross_attention)[0])(lkeys)
        seg_params.append(stacked)
        seg_axes.append(prefix_axes(ax, None))
    p["segments"] = seg_params
    a["segments"] = seg_axes
    if cfg.shared_attn_every:
        p["shared_block"], a["shared_block"] = _block_init(
            keys[2], cfg, SHARED_ATTN, False, dtype)
    p["final_norm"], a["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = dense_init(
            keys[3], cfg.d_model, cfg.padded_vocab, None, "vocab", dtype)
    if cfg.encoder_layers:
        ek = jax.random.split(keys[4], cfg.encoder_layers)
        _, ax = _block_init(ek[0], cfg, ATTN, False, dtype)
        stacked = jax.vmap(
            lambda k: _block_init(k, cfg, ATTN, False, dtype)[0])(ek)
        p["encoder"] = {"blocks": stacked}
        a["encoder"] = {"blocks": prefix_axes(ax, None)}
        p["encoder"]["final_norm"], a["encoder"]["final_norm"] = \
            rmsnorm_init(cfg.d_model, dtype)
    if cfg.frontend == "vision_stub":
        p["frontend_proj"], a["frontend_proj"] = dense_init(
            keys[5], cfg.frontend_dim, cfg.d_model, None, None, dtype)
    if cfg.mtp_depth:
        p["mtp"] = {}
        a["mtp"] = {}
        p["mtp"]["proj"], a["mtp"]["proj"] = dense_init(
            keys[6], 2 * cfg.d_model, cfg.d_model, None, None, dtype)
        p["mtp"]["block"], a["mtp"]["block"] = _block_init(
            keys[7], cfg, ATTN, False, dtype)
        p["mtp"]["norm"], a["mtp"]["norm"] = rmsnorm_init(cfg.d_model, dtype)
    return p, a


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _layer_slice(stacked_params, i: int):
    return jax.tree.map(lambda x: x[i], stacked_params)


def _scan_segment(fn, x, stacked_params, remat: bool, count: int,
                  scan: bool = True):
    """Scan a homogeneous segment; fn(params_i, x) -> (x, aux).

    ``scan=False`` unrolls (used by the cost model: XLA's cost_analysis
    counts a while-loop body once, so the roofline extrapolates from small
    unrolled variants — launch/costmodel.py).
    """
    body = jax.checkpoint(fn) if remat else fn

    if not scan:
        aux = jnp.zeros((), jnp.float32)
        for i in range(count):
            x, a = body(_layer_slice(stacked_params, i), x)
            aux = aux + a
        return x, aux

    def step(carry, lp):
        x, aux = carry
        x, a = body(lp, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               stacked_params)
    return x, aux


def _embed_inputs(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    dtype = cfg.activation_dtype
    x = embed(params["embed"], batch["tokens"], dtype)
    if cfg.frontend == "vision_stub":
        vis = dense(params["frontend_proj"], batch["patches"].astype(dtype))
        x = jnp.concatenate([vis, x], axis=1)
    x = wlc(x, ("batch", "seq", "d_model"))
    return x


def _run_encoder(params, cfg: ModelConfig, frames) -> jnp.ndarray:
    x = frames.astype(cfg.activation_dtype)

    def f(lp, h):
        return _block_train(lp, cfg, ATTN, False, h, causal=False)

    x, _ = _scan_segment(f, x, params["encoder"]["blocks"], cfg.remat,
                         cfg.encoder_layers, scan=cfg.scan_layers)
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _logits(params, cfg: ModelConfig, x) -> jnp.ndarray:
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["lm_head"], x)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    if cfg.padded_vocab != cfg.vocab_size:
        # mask pad columns so softmax/argmax semantics are unchanged while
        # the logits stay shardable over `model`
        col = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(col[None, None, :] < cfg.vocab_size, logits,
                           jnp.asarray(-1e9, logits.dtype))
    return wlc(logits, ("batch", "seq", "vocab"))


def forward_train(params, cfg: ModelConfig, batch
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """batch: {tokens (B,S), [patches|frames]} → (logits (B,S*,V), aux)."""
    x = _embed_inputs(params, cfg, batch)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, batch["frames"])
    aux_total = jnp.zeros((), jnp.float32)
    segs = cfg.segments()
    for sp, (kind, is_moe, count) in zip(params["segments"], segs):
        if kind == SHARED_ATTN:
            x, aux = _block_train(params["shared_block"], cfg, SHARED_ATTN,
                                  False, x, enc_out=enc_out)
        else:
            def f(lp, h, _kind=kind, _moe=is_moe):
                return _block_train(lp, cfg, _kind, _moe, h, enc_out=enc_out)
            x, aux = _scan_segment(f, x, sp, cfg.remat, count,
                                   scan=cfg.scan_layers)
        aux_total = aux_total + aux
    logits = _logits(params, cfg, x)

    if cfg.mtp_depth and "mtp" in params:
        # multi-token prediction: combine h_t with emb(token_{t+1}) and run
        # one extra block to predict token_{t+2} (dsv3 §MTP, depth 1).
        emb_next = embed(params["embed"], batch["tokens"], x.dtype)
        emb_next = jnp.roll(emb_next, -1, axis=1)
        if cfg.frontend == "vision_stub":
            pad = x.shape[1] - emb_next.shape[1]
            emb_next = jnp.pad(emb_next, ((0, 0), (pad, 0), (0, 0)))
        h = dense(params["mtp"]["proj"],
                  jnp.concatenate([x, emb_next], axis=-1))
        h, _ = _block_train(params["mtp"]["block"], cfg, ATTN, False, h)
        h = rmsnorm(params["mtp"]["norm"], h, cfg.norm_eps)
        mtp_logits = _logits(params, cfg, h)
        return logits, aux_total, mtp_logits
    return logits, aux_total, None


def prefill(params, cfg: ModelConfig, batch):
    """Full-prefix forward building decode caches.

    Returns (logits (B, S, V), caches).
    """
    x = _embed_inputs(params, cfg, batch)
    enc_out = None
    caches: Dict[str, Any] = {"index": x.shape[1], "segments": []}
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, batch["frames"])
    segs = cfg.segments()
    aux = jnp.zeros((), jnp.float32)
    for sp, (kind, is_moe, count) in zip(params["segments"], segs):
        if kind == SHARED_ATTN:
            x, _, c = _block_prefill(params["shared_block"], cfg, SHARED_ATTN,
                                     False, x, enc_out=enc_out)
            caches["segments"].append(c)
        else:
            def f(h, lp, _kind=kind, _moe=is_moe):
                h, a, c = _block_prefill(lp, cfg, _kind, _moe, h,
                                         enc_out=enc_out)
                return h, c
            if cfg.scan_layers:
                x, cs = jax.lax.scan(f, x, sp)
            else:
                outs = []
                for i in range(count):
                    x, c = f(x, _layer_slice(sp, i))
                    outs.append(c)
                cs = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            caches["segments"].append(cs)
    return _logits(params, cfg, x), caches


def decode_step(params, cfg: ModelConfig, token: jnp.ndarray, caches,
                index) -> Tuple[jnp.ndarray, Any]:
    """token (B, 1) int32; index: scalar current position. → (logits, caches)."""
    dtype = cfg.activation_dtype
    x = embed(params["embed"], token, dtype)
    segs = cfg.segments()
    new_caches = {"index": index + 1, "segments": []}
    for sp, c, (kind, is_moe, count) in zip(params["segments"],
                                            caches["segments"], segs):
        if kind == SHARED_ATTN:
            x, nc = _block_decode(params["shared_block"], cfg, SHARED_ATTN,
                                  False, x, c, index)
            new_caches["segments"].append(nc)
        else:
            def f(h, xs, _kind=kind, _moe=is_moe):
                lp, lc = xs
                h, nc = _block_decode(lp, cfg, _kind, _moe, h, lc, index)
                return h, nc
            if cfg.scan_layers:
                x, ncs = jax.lax.scan(f, x, (sp, c))
            else:
                outs = []
                for i in range(count):
                    x, nc = f(x, (_layer_slice(sp, i), _layer_slice(c, i)))
                    outs.append(nc)
                ncs = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            new_caches["segments"].append(ncs)
    logits = _logits(params, cfg, x)
    return logits[:, 0, :], new_caches
