"""Attention: GQA (RoPE, qk-norm, qkv-bias) and MLA (DeepSeek-V3).

Three entry points per variant:

* ``*_train``   — full-sequence causal (or bidirectional) attention.  Long
  sequences use an online-softmax scan over KV chunks so the score matrix is
  never fully materialized (chunked flash-style attention in pure JAX).
* ``*_prefill`` — train-path forward that also returns the KV cache.
* ``*_decode``  — one query token against a KV cache (in-place cache update).

MLA caches only the compressed latent (kv_lora_rank + rope_head_dim per
position) — the memory win that makes deepseek-v3 32k/500k serving viable.
The default decode path *expands* the latent to full K/V per step; the
"absorbed" variant (fold W_uk into the query head) is implemented as an
option and studied in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import with_logical_constraint as wlc
from .config import ModelConfig
from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, Any]

ATTN_CHUNK_Q = 1024  # query chunk for online-softmax attention
ATTN_CHUNK_K = 2048  # KV chunk

# Cost-model mode (launch/costmodel.py): disable chunking so attention flops
# appear outside while-loops, where cost_analysis can count them.
_NO_CHUNK = False

# Accumulation mode (§Perf lever): "f32" casts K/V chunks to fp32 before the
# score/AV einsums (baseline, belt-and-braces numerics); "bf16" keeps chunks
# in bf16 and relies on preferred_element_type=f32 MXU accumulation — halves
# attention HBM traffic with the same accumulation precision.
_ACCUM_MODE = "bf16"  # §Perf default: bf16 chunks, f32 accum


def set_no_chunk(flag: bool) -> None:
    global _NO_CHUNK
    _NO_CHUNK = flag


def set_accum_mode(mode: str) -> None:
    assert mode in ("f32", "bf16")
    global _ACCUM_MODE
    _ACCUM_MODE = mode


def set_chunk_sizes(q: int, k: int) -> None:
    """§Perf lever: chunk shapes trade VMEM/temp footprint against the number
    of in-loop iterations (collectives trapped inside the chunk scans execute
    per iteration — fewer, larger chunks shrink the collective term)."""
    global ATTN_CHUNK_Q, ATTN_CHUNK_K
    ATTN_CHUNK_Q, ATTN_CHUNK_K = q, k


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], d, h * hd, None, "heads", dtype,
                                  bias=cfg.qkv_bias)
    p["wk"], a["wk"] = dense_init(ks[1], d, kv * hd, None, "kv_heads", dtype,
                                  bias=cfg.qkv_bias)
    p["wv"], a["wv"] = dense_init(ks[2], d, kv * hd, None, "kv_heads", dtype,
                                  bias=cfg.qkv_bias)
    p["wo"], a["wo"] = dense_init(ks[3], h * hd, d, "heads", None, dtype)
    if cfg.qk_norm:
        p["qnorm"], a["qnorm"] = rmsnorm_init(hd, dtype)
        p["knorm"], a["knorm"] = rmsnorm_init(hd, dtype)
    return p, a


def _qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(B, S, h, hd)
    k = dense(p["wk"], x).reshape(B, S, kv, hd)
    v = dense(p["wv"], x).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(p["knorm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_inner(qh, kc, vc, causal: bool, q_pos, scale: float):
    """Online-softmax over KV chunks.  qh: (B,Sq,KV,g,D); kc/vc chunked
    (n_chunks, B, Ck, KV, D); q_pos: (Sq,) global query positions."""
    B, Sq, KV, groups, D = qh.shape
    n_chunks, _, Ck, _, _ = kc.shape

    def chunk_step(carry, inputs):
        m, l, acc = carry
        idx, kb, vb = inputs
        s = jnp.einsum("bqkgd,bckd->bqkgc", qh, kb,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            k_pos = idx * Ck + jnp.arange(Ck)
            mask = q_pos[:, None] >= k_pos[None, :]            # (Sq,Ck)
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new = -inf): keep exp at 0
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p_ = jnp.exp(s - m_safe[..., None])
        p_ = jnp.where(jnp.isfinite(s), p_, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p_, axis=-1)
        p_v = p_.astype(vb.dtype) if _ACCUM_MODE == "bf16" else p_
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p_v, vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, groups), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, groups), dtype=jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, groups, D), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(chunk_step, (m0, l0, acc0),
                                  (jnp.arange(n_chunks), kc, vc))
    return acc / jnp.maximum(l[..., None], 1e-20)


def _sdpa(q, k, v, causal: bool, q_offset=0):
    """Flash-style chunked attention (q and kv both chunked).

    q: (B,Sq,H,D); k,v: (B,Sk,KV,D).  Never materializes more than a
    (Cq, Ck) score block per (batch, head) — prefill_32k stays in budget.
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    groups = H // KV
    scale = 1.0 / math.sqrt(D)

    nk = max(1, Sk // ATTN_CHUNK_K) if Sk % ATTN_CHUNK_K == 0 else 1
    nq = max(1, Sq // ATTN_CHUNK_Q) if Sq % ATTN_CHUNK_Q == 0 else 1
    if _NO_CHUNK:
        nk = nq = 1
    Ck = Sk // nk
    chunk_dtype = k.dtype if _ACCUM_MODE == "bf16" else jnp.float32
    kc = jnp.moveaxis(k.reshape(B, nk, Ck, KV, D), 1, 0).astype(chunk_dtype)
    vc = jnp.moveaxis(v.reshape(B, nk, Ck, KV, D), 1, 0).astype(chunk_dtype)

    Cq = Sq // nq
    qh = q.reshape(B, nq, Cq, KV, groups, D)

    def q_chunk(idx):
        q_pos = q_offset + idx * Cq + jnp.arange(Cq)
        out = _sdpa_inner(qh[:, idx], kc, vc, causal, q_pos, scale)
        return out                                            # (B,Cq,KV,g,D)

    if nq == 1:
        out = q_chunk(0)
        return out.reshape(B, Sq, H, D).astype(q.dtype)
    outs = jax.lax.map(q_chunk, jnp.arange(nq))               # (nq,B,Cq,KV,g,D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def gqa_train(p, cfg: ModelConfig, x, *, causal: bool = True):
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    q = wlc(q, ("batch", None, "heads", "head_dim"))
    k = wlc(k, ("batch", None, "kv_heads", "head_dim"))
    out = _sdpa(q, k, v, causal=causal)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return dense(p["wo"], out)


def gqa_prefill(p, cfg: ModelConfig, x):
    """Returns (output, cache) — cache = (k, v) over the full prefix."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    out = _sdpa(q, k, v, causal=True)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return dense(p["wo"], out), {"k": k, "v": v}


def gqa_decode(p, cfg: ModelConfig, x, cache, index):
    """x: (B, 1, d); cache k/v: (B, S_max, KV, D); index: () current length."""
    B = x.shape[0]
    positions = jnp.full((B, 1), index, dtype=jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, index, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, index, 0, 0))
    k = wlc(k, ("batch", "cache_seq", "kv_heads", "head_dim"))
    v = wlc(v, ("batch", "cache_seq", "kv_heads", "head_dim"))
    S_max = k.shape[1]
    groups = cfg.num_heads // cfg.num_kv_heads
    qh = q.reshape(B, 1, cfg.num_kv_heads, groups, cfg.head_dim)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qh, k.astype(q.dtype))
    s = s / math.sqrt(cfg.head_dim)
    valid = jnp.arange(S_max)[None, None, None, None, :] <= index
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bqkgs,bskd->bqkgd", w, v.astype(q.dtype))
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return dense(p["wo"], out), {"k": k, "v": v}


def gqa_cross(p, cfg: ModelConfig, x, enc_kv):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(B, S, h, hd)
    out = _sdpa(q, enc_kv["k"], enc_kv["v"], causal=False)
    out = out.reshape(B, S, h * hd)
    return dense(p["wo"], out)


def cross_kv(p, cfg: ModelConfig, enc_out):
    B, S, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = dense(p["wk"], enc_out).reshape(B, S, kv, hd)
    v = dense(p["wv"], enc_out).reshape(B, S, kv, hd)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, deepseek-v3)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.num_heads
    hd, rd, vd = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["wq_a"], a["wq_a"] = dense_init(ks[0], d, qr, None, None, dtype)
    p["qnorm"], a["qnorm"] = rmsnorm_init(qr, dtype)
    p["wq_b"], a["wq_b"] = dense_init(ks[1], qr, h * (hd + rd), None, "heads",
                                      dtype)
    p["wkv_a"], a["wkv_a"] = dense_init(ks[2], d, kvr + rd, None, None, dtype)
    p["kvnorm"], a["kvnorm"] = rmsnorm_init(kvr, dtype)
    p["wkv_b"], a["wkv_b"] = dense_init(ks[3], kvr, h * (hd + vd), None,
                                        "heads", dtype)
    p["wo"], a["wo"] = dense_init(ks[4], h * vd, d, "heads", None, dtype)
    return p, a


def _mla_q(p, cfg, x, positions):
    B, S, _ = x.shape
    h, hd, rd = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    q = dense(p["wq_b"], rmsnorm(p["qnorm"], dense(p["wq_a"], x),
                                 cfg.norm_eps))
    q = q.reshape(B, S, h, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    kvr, rd = cfg.kv_lora_rank, cfg.rope_head_dim
    kv = dense(p["wkv_a"], x)                       # (B, S, kvr + rd)
    c_kv = rmsnorm(p["kvnorm"], kv[..., :kvr], cfg.norm_eps)
    k_rope = apply_rope(kv[..., kvr:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _mla_expand(p, cfg, c_kv):
    """Latent → per-head K(nope)/V. (B, S, kvr) → (B, S, H, hd)+(B, S, H, vd)."""
    B, S, _ = c_kv.shape
    h, hd, vd = cfg.num_heads, cfg.head_dim, cfg.v_head_dim
    kvb = dense(p["wkv_b"], c_kv).reshape(B, S, h, hd + vd)
    return kvb[..., :hd], kvb[..., hd:]


def _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v):
    """Chunked MLA attention via effective concat heads.

    q_eff = [q_nope; q_rope], k_eff = [k_nope; k_rope⊗heads]; v is padded to
    the same head_dim so the shared _sdpa path applies (padding columns of v
    contribute zeros and are sliced off).
    """
    B, Sq, H, hd = q_nope.shape
    vd = v.shape[-1]
    rd = q_rope.shape[-1]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, k_rope.shape[1], H, rd))
    q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_eff = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    D_eff = hd + rd
    if vd < D_eff:
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, D_eff - vd)))
    else:
        v_pad = v
    out = _sdpa(q_eff, k_eff, v_pad, causal=True)
    return out[..., :vd]


def mla_train(p, cfg: ModelConfig, x):
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope, v = _mla_expand(p, cfg, c_kv)
    out = _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v)
    out = out.reshape(B, S, cfg.num_heads * cfg.v_head_dim)
    return dense(p["wo"], out)


def mla_prefill(p, cfg: ModelConfig, x):
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope, v = _mla_expand(p, cfg, c_kv)
    out = _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v)
    out = out.reshape(B, S, cfg.num_heads * cfg.v_head_dim)
    return dense(p["wo"], out), {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(p, cfg: ModelConfig, x, cache, index, *, absorbed: bool = True):
    """MLA decode against the latent cache.

    absorbed=True folds W_uk into the query (score = (q W_uk) · c_kv) and
    attends in latent space, so per-step cost is O(S·kvr) instead of
    O(S·H·hd) for latent expansion — the beyond-paper §Perf optimization.
    """
    B = x.shape[0]
    h, hd, vd, kvr = cfg.num_heads, cfg.head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    positions = jnp.full((B, 1), index, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)          # (B,1,H,hd/rd)
    c_new, kr_new = _mla_latent(p, cfg, x, positions)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, index, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, index, 0))
    c_kv = wlc(c_kv, ("batch", "cache_seq", None))
    S_max = c_kv.shape[1]
    scale = 1.0 / math.sqrt(hd + cfg.rope_head_dim)

    wkv_b = p["wkv_b"]["w"].astype(x.dtype).reshape(kvr, h, hd + vd)
    w_uk = wkv_b[..., :hd]                                  # (kvr, H, hd)
    w_uv = wkv_b[..., hd:]                                  # (kvr, H, vd)
    if absorbed:
        q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)  # (B,1,H,kvr)
        s = (jnp.einsum("bqhc,bsc->bhqs", q_lat, c_kv.astype(x.dtype)) +
             jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope.astype(x.dtype)))
    else:
        kvb = dense(p["wkv_b"], c_kv.astype(x.dtype)).reshape(
            B, S_max, h, hd + vd)
        s = (jnp.einsum("bqhd,bshd->bhqs", q_nope, kvb[..., :hd]) +
             jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope.astype(x.dtype)))
    s = s * scale
    valid = jnp.arange(S_max)[None, None, None, :] <= index
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    if absorbed:
        o_lat = jnp.einsum("bhqs,bsc->bqhc", w, c_kv.astype(x.dtype))
        out = jnp.einsum("bqhc,chd->bqhd", o_lat, w_uv)      # (B,1,H,vd)
    else:
        out = jnp.einsum("bhqs,bshd->bqhd", w, kvb[..., hd:])
    out = out.reshape(B, 1, h * vd)
    return dense(p["wo"], out), {"c_kv": c_kv, "k_rope": k_rope}
