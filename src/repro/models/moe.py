"""Mixture-of-Experts: top-k routing, capacity-based dispatch, shared experts.

Dispatch uses the scatter formulation (no (T, E, C) one-hot, no sort): per
routing choice, position-in-expert comes from a (T, E) cumsum; tokens scatter
into (E·C, d) slot buffers and gather back with their gate weights.  Expert
FFNs run as stacked einsums over the expert dimension, which shards over the
`model` mesh axis (expert parallelism) — under GSPMD the scatter/gather turn
into the MoE all-to-alls, visible in the roofline's collective term.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..jaxcompat import current_mesh, shard_map
from ..sharding import with_logical_constraint as wlc
from .config import ModelConfig, MoEConfig
from .layers import Params, dense_init, mlp, mlp_init


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    mult_names = ["wi", "wg", "wo"] if cfg.mlp == "swiglu" else ["wi", "wo"]
    p: Params = {}
    a: Params = {}
    p["router"], a["router"] = dense_init(ks[0], d, m.num_experts, None, None,
                                          dtype)
    # stacked expert weights: (E, d, ff) / (E, ff, d)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(m.d_ff)
    shapes = {"wi": (m.num_experts, d, m.d_ff),
              "wg": (m.num_experts, d, m.d_ff),
              "wo": (m.num_experts, m.d_ff, d)}
    axes = {"wi": ("experts", "fsdp", "expert_ffn"),
            "wg": ("experts", "fsdp", "expert_ffn"),
            "wo": ("experts", "expert_ffn", "fsdp")}
    for i, name in enumerate(mult_names):
        std = std_out if name == "wo" else std_in
        w = jax.random.normal(ks[1 + i], shapes[name], jnp.float32) * std
        p[name] = w.astype(dtype)
        a[name] = axes[name]
    if m.num_shared_experts:
        p["shared"], a["shared"] = mlp_init(
            ks[6], d, m.num_shared_experts * m.shared_d_ff, cfg.mlp, dtype)
    return p, a


def _expert_ffn(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """x: (E, C, d) → (E, C, d) with per-expert weights."""
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["wg"].astype(x.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", x, p["wi"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["wi"].astype(x.dtype)))
    h = wlc(h, ("experts", None, "expert_ffn"))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))


def moe_apply(p: Params, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → (y, aux_loss).

    Two paths:

    * **on-mesh (production)**: explicit expert parallelism under shard_map.
      Experts shard over `model`; activations are replicated across `model`
      (d_model is unsharded), so each shard selects the tokens routed to its
      own experts locally — no all-to-all for dispatch — runs its expert
      FFNs, and a single `psum` over `model` combines expert contributions
      (it fuses with the TP output reduction).  Capacity is applied per
      (data-shard, expert).  This exists because both GSPMD-auto
      formulations failed at scale: scatter-of-activations replicated an
      (E·C, d) buffer (+311 GB/dev all-reduce), gather-from-sharded-source
      replicated the expert buffer (520 GB/dev temps) — EXPERIMENTS.md
      §Perf logs the progression.
    * **off-mesh (host tests)**: the same math, single shard.
    """
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        return _moe_sharded(p, cfg, x, mesh)
    return _moe_global(p, cfg, x)


def _moe_global(p: Params, cfg: ModelConfig, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    E, k = m.num_experts, m.top_k
    cap = max(1, int(m.capacity_factor * T * k / E))

    logits = xf.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate_vals, choices = jax.lax.top_k(probs, k)                # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)       # renormalize

    # ---- sort-based slot assignment (indices only) ----------------------
    flat_e = choices.reshape(T * k)                             # expert ids
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k          # token ids
    order = jnp.argsort(flat_e, stable=True)                    # group by e
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)       # (E,)
    starts = jnp.cumsum(counts) - counts                        # exclusive
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep_sorted = pos_sorted < cap
    slot_sorted = sorted_e * cap + jnp.minimum(pos_sorted, cap - 1)
    # slot -> token map (pad slots point at the zero row T)
    slot_tok = jnp.full((E * cap,), T, jnp.int32)
    slot_tok = slot_tok.at[slot_sorted].set(
        jnp.where(keep_sorted, flat_tok[order], T))

    # ---- dispatch (gather), expert FFN, combine (gather) -----------------
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    expert_in = x_pad[slot_tok].reshape(E, cap, d)
    expert_in = wlc(expert_in, ("experts", "fsdp", None))
    expert_out = _expert_ffn(p, expert_in, cfg.mlp)
    expert_out = wlc(expert_out, ("experts", "fsdp", None))
    expert_out = expert_out.reshape(E * cap, d)

    # inverse permutation: flat entry -> its sorted position
    inv = jnp.zeros((T * k,), jnp.int32).at[order].set(
        jnp.arange(T * k, dtype=jnp.int32))
    pos = pos_sorted[inv]                                       # (T*k,)
    keep = (pos < cap).reshape(T, k)
    slot = (flat_e * cap + jnp.minimum(pos, cap - 1)).reshape(T, k)
    y = jnp.zeros_like(xf)
    for i in range(k):  # k gathers of (T, d), accumulated in place
        contrib = expert_out[slot[:, i]]
        w = (gate_vals[:, i] * keep[:, i]).astype(x.dtype)
        y = y + contrib * w[:, None]
    if m.num_shared_experts:
        y = y + mlp(p["shared"], xf, cfg.mlp)

    # load-balancing aux loss (Switch-style)
    frac_tokens = counts.astype(jnp.float32) / jnp.float32(T * k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * m.router_aux_weight
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# explicit-EP path (shard_map)
# ---------------------------------------------------------------------------


def _local_expert_pass(p: Params, cfg: ModelConfig, xf: jnp.ndarray,
                       gate_vals: jnp.ndarray, choices: jnp.ndarray,
                       e_lo: jnp.ndarray, E_local: int) -> jnp.ndarray:
    """Dispatch the local tokens routed to experts [e_lo, e_lo+E_local),
    run the local expert FFNs, combine with gates.  All-local; the caller
    psums across the expert axis."""
    m = cfg.moe
    T, d = xf.shape
    k = m.top_k
    cap = max(1, int(m.capacity_factor * T * k / m.num_experts))

    flat_e = choices.reshape(T * k) - e_lo          # local expert ids
    local = (flat_e >= 0) & (flat_e < E_local)
    flat_e = jnp.where(local, flat_e, E_local)      # E_local = overflow bin
    flat_tok = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E_local + 1,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep_sorted = (pos_sorted < cap) & (sorted_e < E_local)
    slot_sorted = jnp.where(
        keep_sorted, sorted_e * cap + jnp.minimum(pos_sorted, cap - 1),
        E_local * cap)                              # trash slot
    slot_tok = jnp.full((E_local * cap + 1,), T, jnp.int32)
    slot_tok = slot_tok.at[slot_sorted].set(
        jnp.where(keep_sorted, flat_tok[order], T))
    slot_tok = slot_tok[:E_local * cap]

    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    expert_in = x_pad[slot_tok].reshape(E_local, cap, d)
    expert_out = _expert_ffn(p, expert_in, cfg.mlp).reshape(E_local * cap, d)
    expert_out = jnp.concatenate(
        [expert_out, jnp.zeros((1, d), expert_out.dtype)], axis=0)

    # combine: inverse permutation → slot per (token, choice)
    inv = jnp.zeros((T * k,), jnp.int32).at[order].set(
        jnp.arange(T * k, dtype=jnp.int32))
    pos = pos_sorted[inv]
    kept = (pos < cap) & local
    slot = jnp.where(kept,
                     flat_e * cap + jnp.minimum(pos, cap - 1),
                     E_local * cap)
    slot2 = slot.reshape(T, k)
    kept2 = kept.reshape(T, k)
    y = jnp.zeros_like(xf)
    for i in range(k):
        contrib = expert_out[slot2[:, i]]
        w = (gate_vals[:, i] * kept2[:, i]).astype(xf.dtype)
        y = y + contrib * w[:, None]
    return y


# Below this many global tokens (decode / small serving batches), moving
# weights is absurd: regathering fsdp-sharded expert weights costs GBs per
# layer while the token activations are MBs.  The decode path keeps weights
# stationary (E over `model`, d_model over `data`), replicates the tokens,
# contracts each device's d-slice and psums the partial hiddens over `data`
# (§Perf Track 1b).
_TOKEN_STATIONARY_MAX = 512


def _moe_sharded(p: Params, cfg: ModelConfig, x: jnp.ndarray, mesh
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    E = m.num_experts
    names = mesh.axis_names
    sizes = dict(mesh.shape)
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    bsz = 1
    for a in batch_axes:
        bsz *= sizes[a]
    B = x.shape[0]
    if B % bsz != 0:
        batch_axes = tuple(a for a in batch_axes
                           if B % sizes[a] == 0)[:1]  # degrade gracefully
    model_size = sizes["model"]
    if E % model_size != 0:
        return _moe_global(p, cfg, x)
    E_local = E // model_size

    T_global = B * x.shape[1]
    if (T_global <= _TOKEN_STATIONARY_MAX and cfg.mlp == "swiglu"
            and "data" in names and cfg.d_model % sizes["data"] == 0):
        return _moe_decode_stationary(p, cfg, x, mesh)

    # per-leaf param specs: expert weights sharded over `model`, rest repl.
    def pspec(path_leaf):
        name, leaf = path_leaf
        if name in ("wi", "wg", "wo"):
            return P("model", None, None)
        return P(*(None,) * leaf.ndim)

    p_specs = {}
    for name, sub in p.items():
        if name in ("wi", "wg", "wo"):
            p_specs[name] = P("model", None, None)
        elif isinstance(sub, dict):
            p_specs[name] = jax.tree.map(lambda l: P(*(None,) * l.ndim), sub)
        else:
            p_specs[name] = P(*(None,) * sub.ndim)

    x_spec = P(batch_axes if batch_axes else None, None, None)

    def body(p_local, x_local):
        Bl, S, d = x_local.shape
        xf = x_local.reshape(Bl * S, d)
        logits = xf.astype(jnp.float32) @ p_local["router"]["w"].astype(
            jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, choices = jax.lax.top_k(probs, m.top_k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        midx = jax.lax.axis_index("model")
        e_lo = midx * E_local
        y = _local_expert_pass(p_local, cfg, xf, gate_vals, choices,
                               e_lo, E_local)
        # combine expert contributions living on other model shards
        y = jax.lax.psum(y, "model")
        if m.num_shared_experts:
            y = y + mlp(p_local["shared"], xf, cfg.mlp)

        counts = jnp.sum(jax.nn.one_hot(choices, E, dtype=jnp.float32),
                         axis=(0, 1))
        frac_tokens = counts / jnp.float32(xf.shape[0] * m.top_k)
        frac_probs = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(frac_tokens * frac_probs) * m.router_aux_weight
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(Bl, S, d), aux

    y, aux = shard_map(
        body, mesh,
        (p_specs, x_spec),
        (x_spec, P()),
    )(p, x)
    return y, aux


def _moe_decode_stationary(p: Params, cfg: ModelConfig, x: jnp.ndarray, mesh
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Weights-stationary decode MoE: tokens replicate (MBs), weights never
    move.  Each (data_i, model_j) device holds experts j·E_l..(j+1)·E_l with
    the d_model dim sharded over `data`; it contracts its d-slice for ALL
    tokens routed to its experts and the partial hiddens psum over `data`.
    wo runs d-sharded the other way and the output reduce-scatters back to
    the callers' batch sharding via a final psum over `model` + slice."""
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    E, k = m.num_experts, m.top_k
    sizes = dict(mesh.shape)
    model_size = sizes["model"]
    data_size = sizes["data"]
    E_local = E // model_size
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    p_specs = {}
    for name, sub in p.items():
        if name in ("wi", "wg"):
            p_specs[name] = P("model", "data", None)   # stationary: d over data
        elif name == "wo":
            p_specs[name] = P("model", None, "data")
        elif isinstance(sub, dict):
            p_specs[name] = jax.tree.map(lambda l: P(*(None,) * l.ndim), sub)
        else:
            p_specs[name] = P(*(None,) * sub.ndim)

    def body(p_local, x_full):
        Bf, S, d = x_full.shape            # tokens fully replicated
        T = Bf * S
        xf = x_full.reshape(T, d)
        logits = xf.astype(jnp.float32) @ p_local["router"]["w"].astype(
            jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, choices = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        midx = jax.lax.axis_index("model")
        didx = jax.lax.axis_index("data")
        e_lo = midx * E_local
        d_sh = d // jax.lax.psum(1, "data") if False else d // data_size
        # dense per-expert token masks (T small): (E_local, T) gate weights
        w_et = jnp.zeros((E_local, T), jnp.float32)
        for i in range(k):
            onehot = jax.nn.one_hot(choices[:, i] - e_lo, E_local,
                                    dtype=jnp.float32)          # (T, E_l)
            w_et = w_et + onehot.T * gate_vals[:, i][None, :]
        # local d-slice of tokens
        x_slice = jax.lax.dynamic_slice_in_dim(xf, didx * d_sh, d_sh, 1)
        # partial hidden for every (expert, token): contract local d-slice
        hg = jnp.einsum("td,edf->etf", x_slice.astype(p_local["wg"].dtype),
                        p_local["wg"])                           # (E_l,T,f)
        hi = jnp.einsum("td,edf->etf", x_slice.astype(p_local["wi"].dtype),
                        p_local["wi"])
        hg = jax.lax.psum(hg, "data")      # complete the d contraction
        hi = jax.lax.psum(hi, "data")
        h = jax.nn.silu(hg) * hi
        # wo: back to a d-slice, weighted by gates; psum over model combines
        # experts, then gather d-slices across data
        y_slice = jnp.einsum("etf,efd,et->td", h, p_local["wo"],
                             w_et.astype(h.dtype))               # (T, d_sh)
        y_slice = jax.lax.psum(y_slice, "model")
        y = jax.lax.all_gather(y_slice, "data", axis=1, tiled=True)  # (T, d)
        if m.num_shared_experts:
            y = y + mlp(p_local["shared"], xf, cfg.mlp)
        counts = jnp.sum(jax.nn.one_hot(choices, E, dtype=jnp.float32),
                         axis=(0, 1))
        frac_tokens = counts / jnp.float32(T * k)
        frac_probs = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(frac_tokens * frac_probs) * m.router_aux_weight
        # return only this shard's batch slice (out_specs re-shards)
        y = y.reshape(Bf, S, d)
        if batch_axes:
            n_b = 1
            for a in batch_axes:
                n_b *= sizes[a]
            if Bf % n_b == 0:
                bidx = jax.lax.axis_index(batch_axes[0]) if len(batch_axes) == 1                     else (jax.lax.axis_index(batch_axes[0]) * sizes[batch_axes[1]]
                          + jax.lax.axis_index(batch_axes[1]))
                y = jax.lax.dynamic_slice_in_dim(y, bidx * (Bf // n_b),
                                                 Bf // n_b, 0)
        return y, aux

    x_spec = P(batch_axes if batch_axes else None, None, None)
    B = x.shape[0]
    n_b = 1
    for a in batch_axes:
        n_b *= sizes[a]
    out_spec = x_spec if (batch_axes and B % n_b == 0) else P(None, None, None)
    y, aux = shard_map(
        body, mesh,
        (p_specs, P(None, None, None)),   # tokens replicated
        (out_spec, P()),
    )(p, x)
    return y, aux
