from .config import (ATTN, MAMBA2, RWKV6, SHARED_ATTN, ModelConfig, MoEConfig,
                     SSMConfig)
from .stack import decode_step, forward_train, init_params, prefill
from .steps import (cross_entropy, init_decode_caches, init_train_state,
                    loss_fn, make_prefill_step, make_serve_step,
                    make_train_step)

__all__ = ["ATTN", "MAMBA2", "RWKV6", "SHARED_ATTN", "ModelConfig",
           "MoEConfig", "SSMConfig", "decode_step", "forward_train",
           "init_params", "prefill", "cross_entropy", "init_decode_caches",
           "init_train_state", "loss_fn", "make_prefill_step",
           "make_serve_step", "make_train_step"]
