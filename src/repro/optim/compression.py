"""int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce path).

Gradients are quantized per-tensor to int8 around a fp32 scale before the
data-parallel all-reduce, and the quantization error is fed back into the
next step's gradients (error-feedback keeps SGD/Adam convergence — Karimireddy
et al. 2019).  8× less DP traffic; the multi-pod roofline's collective term
drops accordingly (§Perf).

Usage inside train_step::

    grads, err = compress_gradients(grads, err)      # quantize + feedback
    grads = jax.lax.pmean(grads, 'data')             # int8 wire format
    grads = decompress_gradients(grads)
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

_LEVELS = 127.0


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / _LEVELS + 1e-12
    q = jnp.clip(jnp.round(g / scale), -_LEVELS, _LEVELS).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compress_gradients(grads: Any, error: Optional[Any]) -> Tuple[Any, Any]:
    """Returns ({'q': int8 tree, 'scale': scalar tree}, new_error_tree)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    flat, treedef = jax.tree.flatten(grads)
    flat_err = treedef.flatten_up_to(error)
    qs, scales, errs = [], [], []
    for g, e in zip(flat, flat_err):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        qs.append(q)
        scales.append(scale)
        errs.append(g32 - q.astype(jnp.float32) * scale)
    return ({"q": treedef.unflatten(qs), "scale": treedef.unflatten(scales)},
            treedef.unflatten(errs))


def decompress_gradients(compressed: Any) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        compressed["q"], compressed["scale"])
