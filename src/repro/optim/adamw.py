"""AdamW with dtype-configurable moments + cosine LR schedule.

Moments can be stored in bfloat16 for the very large configs (deepseek-v3 on
a 16 GB/chip pod would not fit fp32 m/v — see EXPERIMENTS.md §Dry-run memory
table); update math always runs in fp32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    moment_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params: Any, grads: Any, state: Any, cfg: AdamWConfig
                 ) -> Tuple[Any, Any, dict]:
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step.astype(jnp.float32))
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        nu32 = nu.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mhat = mu32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        nhat = nu32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
