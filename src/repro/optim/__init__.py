from .adamw import (AdamWConfig, adamw_init, adamw_update, cosine_schedule,
                    global_norm)
from .compression import compress_gradients, decompress_gradients

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "compress_gradients", "decompress_gradients"]
