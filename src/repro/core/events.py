"""Event model for CORE (paper §3).

Events are *data-tuples*: partial mappings from attribute names to data values,
each associated with an event type.  A stream is a (possibly unbounded) sequence
of data-tuples; CORE assigns each tuple the position at which it arrives.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Iterator, Optional

NULL = None  # paper: t(a) = NULL when t is undefined on attribute a


class Event:
    """A data-tuple ``t`` with an event type and attribute map.

    ``t(type)`` is exposed as ``.type``; ``t(a)`` as ``.get(a)`` (NULL if absent).
    ``position`` / ``timestamp`` are assigned by the engine on arrival (the paper
    assigns arrival order; time-attribute windows like ``WITHIN 30000 [stock_time]``
    read the timestamp from the named attribute instead).
    """

    __slots__ = ("type", "attrs", "position", "timestamp")

    def __init__(self, type: str, attrs: Optional[Dict[str, Any]] = None,
                 position: int = -1, timestamp: Optional[float] = None):
        self.type = type
        self.attrs = attrs or {}
        self.position = position
        self.timestamp = timestamp

    def get(self, attr: str) -> Any:
        if attr == "type":
            return self.type
        return self.attrs.get(attr, NULL)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Event({self.type}@{self.position} {self.attrs})"


@dataclass(frozen=True)
class ComplexEvent:
    """A complex event ``C = ([i, j], D)`` (paper §3).

    ``start``/``end`` are stream positions; ``data`` the sorted tuple of the
    positions of the relevant data-tuples (``D ⊆ {i..j}``).
    """

    start: int
    end: int
    data: tuple  # sorted tuple of positions

    @property
    def time(self):
        return (self.start, self.end)

    def __len__(self) -> int:
        return len(self.data)


@dataclass(frozen=True)
class Valuation:
    """A valuation ``V = ([i, j], μ)`` mapping variables to position sets."""

    start: int
    end: int
    mapping: tuple  # tuple of (variable, frozenset(positions)) sorted by variable

    def to_complex_event(self) -> ComplexEvent:
        data = set()
        for _, positions in self.mapping:
            data |= positions
        return ComplexEvent(self.start, self.end, tuple(sorted(data)))

    def var(self, name: str) -> frozenset:
        for var, positions in self.mapping:
            if var == name:
                return positions
        return frozenset()


def stream_from_types(types: Iterable[str], **attr_fns) -> Iterator[Event]:
    """Tiny helper: build a stream of attribute-less events from type names."""
    for i, t in enumerate(types):
        attrs = {k: fn(i) for k, fn in attr_fns.items()}
        yield Event(t, attrs, position=i, timestamp=float(i))


def assign_positions(stream: Iterable[Event], time_attr: Optional[str] = None
                     ) -> Iterator[Event]:
    """Assign arrival positions (and timestamps) to a raw stream of events.

    The paper: "each event is assigned the time at which it arrives to the
    system".  If ``time_attr`` is given, timestamps are read from that attribute
    (used by the stock queries' ``WITHIN 30000 [stock_time]``).
    """
    for i, ev in enumerate(stream):
        ev.position = i
        if time_attr is not None:
            ev.timestamp = float(ev.get(time_attr))
        elif ev.timestamp is None:
            ev.timestamp = float(i)
        yield ev
