"""CEQL — CORE's surface query language (paper §2–3).

    SELECT [strategy] <vars | *> FROM <streams>
    WHERE <CEL formula> [FILTER <var[cond]> {AND|OR <var[cond]>}*]
    [PARTITION BY [attr] {, [attr]}*]
    [WITHIN <n> (events | ms | seconds | minutes | hours) | <n> [time_attr]]
    [CONSUME BY (ANY | NONE)]

A hand-written tokenizer + recursive-descent parser.  The WHERE clause parses
to a CEL AST (:mod:`repro.core.cel`); the FILTER clause is sugar for CEL
FILTER per footnote 1 of the paper:  ``φ FILTER θ1 AND θ2 ≡ (φ FILTER θ1)
FILTER θ2`` and ``φ FILTER θ1 OR θ2 ≡ (φ FILTER θ1) OR (φ FILTER θ2)``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from . import cel as C
from .engine import WindowSpec
from .predicates import (AtomicPredicate, PAnd, PAtom, PNot, POr, PredExpr,
                         PTrue)

_TOKEN_RE = re.compile(r"""
    (?P<ws>\s+)
  | (?P<num>-?\d+(\.\d+)?)
  | (?P<str>'[^']*'|"[^"]*")
  | (?P<op><=|>=|!=|==|<|>|=)
  | (?P<punc>[()\[\];,+*])
  | (?P<word>[A-Za-z_][A-Za-z_0-9.']*)
""", re.VERBOSE)

_KEYWORDS = {"SELECT", "FROM", "WHERE", "FILTER", "PARTITION", "BY", "WITHIN",
             "AND", "OR", "AS", "CONSUME", "NONE", "ANY"}
_STRATEGIES = {"ALL", "ANY", "NEXT", "NXT", "LAST", "MAX", "STRICT"}
_UNITS = {"event": 1, "events": 1,
          "ms": 1e-3, "millisecond": 1e-3, "milliseconds": 1e-3,
          "s": 1.0, "sec": 1.0, "second": 1.0, "seconds": 1.0,
          "min": 60.0, "minute": 60.0, "minutes": 60.0,
          "hour": 3600.0, "hours": 3600.0}


@dataclass
class Token:
    kind: str
    value: str


def tokenize(text: str) -> List[Token]:
    tokens, i = [], 0
    while i < len(text):
        m = _TOKEN_RE.match(text, i)
        if not m:
            raise SyntaxError(f"CEQL: cannot tokenize at ...{text[i:i+20]!r}")
        i = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        tokens.append(Token(kind, m.group()))
    return tokens


@dataclass
class Query:
    """Parsed CEQL query, ready for compilation + evaluation."""

    select: Optional[Tuple[str, ...]]      # None ⇒ SELECT *
    strategy: str                          # ALL (default) | NXT | LAST | MAX
    streams: Tuple[str, ...]
    where: C.CEL                           # CEL formula (FILTERs folded in)
    partition_by: Tuple[str, ...]
    window: WindowSpec
    consume_on_match: bool
    text: str = ""

    def formula(self) -> C.CEL:
        """WHERE + SELECT projection as a single CEL formula."""
        phi = self.where
        if self.select is not None:
            phi = C.Proj(phi, frozenset(self.select))
        return phi


class _Parser:
    def __init__(self, tokens: List[Token], text: str):
        self.toks = tokens
        self.pos = 0
        self.text = text

    # -- token helpers -------------------------------------------------------
    def peek(self) -> Optional[Token]:
        return self.toks[self.pos] if self.pos < len(self.toks) else None

    def next(self) -> Token:
        t = self.peek()
        if t is None:
            raise SyntaxError("CEQL: unexpected end of query")
        self.pos += 1
        return t

    def accept_word(self, *words: str) -> Optional[str]:
        t = self.peek()
        if t and t.kind == "word" and t.value.upper() in words:
            self.pos += 1
            return t.value.upper()
        return None

    def expect_word(self, word: str) -> None:
        if not self.accept_word(word):
            raise SyntaxError(f"CEQL: expected {word} near token {self.pos}: "
                              f"{self.peek()}")

    def accept_punc(self, p: str) -> bool:
        t = self.peek()
        if t and t.kind == "punc" and t.value == p:
            self.pos += 1
            return True
        return False

    def expect_punc(self, p: str) -> None:
        if not self.accept_punc(p):
            raise SyntaxError(f"CEQL: expected {p!r} got {self.peek()}")

    # -- grammar ---------------------------------------------------------------
    def parse(self) -> Query:
        self.expect_word("SELECT")
        strategy = "ALL"
        t = self.peek()
        if t and t.kind == "word" and t.value.upper() in _STRATEGIES:
            nxt = self.toks[self.pos + 1] if self.pos + 1 < len(self.toks) else None
            # disambiguate `SELECT MAX *` (strategy) from `SELECT max FROM`
            # (a plain variable named `max`)
            if nxt and (nxt.value == "*" or
                        (nxt.kind == "word" and nxt.value.upper() != "FROM")):
                strategy = t.value.upper()
                if strategy == "NEXT":
                    strategy = "NXT"
                self.pos += 1
        select: Optional[Tuple[str, ...]]
        if self.accept_punc("*"):
            select = None
        else:
            names = [self.next().value]
            while self.accept_punc(","):
                names.append(self.next().value)
            select = tuple(names)
        self.expect_word("FROM")
        streams = [self.next().value]
        while self.accept_punc(","):
            streams.append(self.next().value)
        self.expect_word("WHERE")
        where = self._cel_or()
        if self.accept_word("FILTER"):
            where = self._filters(where)
        # trailing clauses in any order (the paper writes PARTITION BY before
        # WITHIN; we accept both orders)
        partition: List[str] = []
        window = WindowSpec()
        consume = False
        while True:
            if self.accept_word("PARTITION"):
                self.expect_word("BY")
                partition.append(self._bracketed_attr())
                while self.accept_punc(","):
                    partition.append(self._bracketed_attr())
            elif self.accept_word("WITHIN"):
                window = self._window()
            elif self.accept_word("CONSUME"):
                self.expect_word("BY")
                if self.accept_word("ANY"):
                    consume = True
                else:
                    self.expect_word("NONE")
            else:
                break
        if self.peek() is not None:
            raise SyntaxError(f"CEQL: trailing tokens at {self.peek()}")
        return Query(select, strategy, tuple(streams), where, tuple(partition),
                     window, consume, self.text)

    def _bracketed_attr(self) -> str:
        self.expect_punc("[")
        name = self.next().value
        self.expect_punc("]")
        return name

    def _window(self) -> WindowSpec:
        t = self.next()
        if t.kind != "num":
            raise SyntaxError(f"CEQL: WITHIN expects a number, got {t}")
        n = float(t.value)

        def event_count() -> WindowSpec:
            # count windows take whole event counts; silently truncating
            # `WITHIN 2.5` to 2 events would change query semantics
            if not n.is_integer():
                raise SyntaxError(
                    f"CEQL: WITHIN expects an integer event count, got "
                    f"{t.value} (time windows need a unit or [time_attr])")
            if n < 0:
                raise SyntaxError(
                    f"CEQL: WITHIN event count must be ≥ 0, got {t.value}")
            return WindowSpec.events(int(n))

        nxt = self.peek()
        if nxt and nxt.kind == "punc" and nxt.value == "[":
            attr = self._bracketed_attr()     # e.g. WITHIN 30000 [stock_time]
            return WindowSpec.time(n, attr)
        if nxt and nxt.kind == "word" and nxt.value.lower() in _UNITS:
            unit = self.next().value.lower()
            if _UNITS[unit] == 1 and unit.startswith("event"):
                return event_count()
            return WindowSpec.time(n * _UNITS[unit])
        return event_count()                  # bare number ⇒ count-based

    # CEL: OR < ';' < postfix(+ / AS)
    def _cel_or(self) -> C.CEL:
        left = self._cel_seq()
        while self.accept_word("OR"):
            left = C.Or(left, self._cel_seq())
        return left

    def _cel_seq(self) -> C.CEL:
        left = self._cel_post()
        while self.accept_punc(";"):
            left = C.Seq(left, self._cel_post())
        return left

    def _cel_post(self) -> C.CEL:
        node = self._cel_atom()
        while True:
            if self.accept_punc("+"):
                node = C.Plus(node)
            elif self.accept_word("AS"):
                node = C.As(node, self.next().value)
            else:
                return node

    def _cel_atom(self) -> C.CEL:
        if self.accept_punc("("):
            node = self._cel_or()
            self.expect_punc(")")
            return node
        t = self.next()
        if t.kind != "word":
            raise SyntaxError(f"CEQL: expected event type, got {t}")
        return C.EventType(t.value)

    # FILTER var[cond] {AND|OR var[cond]}*   (left-assoc, AND == OR precedence,
    # matching the paper's shorthand which is a flat chain)
    def _filters(self, phi: C.CEL) -> C.CEL:
        phi = self._one_filter(phi)
        while True:
            if self.accept_word("AND"):
                phi = self._one_filter(phi)
            elif self.accept_word("OR"):
                phi = C.Or(phi, self._one_filter_into(phi))
            else:
                return phi

    def _one_filter(self, phi: C.CEL) -> C.CEL:
        var, pred = self._filter_atom()
        return C.Filter(phi, var, pred)

    def _one_filter_into(self, phi: C.CEL) -> C.CEL:
        # φ FILTER θ1 OR θ2 ≡ (φ FILTER θ1) OR (φ FILTER θ2): caller passes
        # the *filtered* left branch; we filter the raw φ again.
        base = phi
        while isinstance(base, C.Filter):
            base = base.child
        var, pred = self._filter_atom()
        return C.Filter(base, var, pred)

    def _filter_atom(self) -> Tuple[str, PredExpr]:
        var = self.next().value
        self.expect_punc("[")
        pred = self._attr_cond()
        while self.accept_word("AND"):
            pred = PAnd(pred, self._attr_cond())
        self.expect_punc("]")
        return var, pred

    def _attr_cond(self) -> PredExpr:
        attr = self.next().value
        op = self.next()
        if op.kind != "op":
            raise SyntaxError(f"CEQL: expected comparison op, got {op}")
        opv = "==" if op.value == "=" else op.value
        val_tok = self.next()
        if val_tok.kind == "num":
            v = float(val_tok.value)
            value = int(v) if v.is_integer() and "." not in val_tok.value else v
        elif val_tok.kind == "str":
            value = val_tok.value[1:-1]
        else:
            value = val_tok.value
        return PAtom(AtomicPredicate(attr, opv, value))


def parse(text: str) -> Query:
    return _Parser(tokenize(text), text).parse()
