"""CORE — COmplex event Recognition Engine (host / reference layer).

Faithful implementation of the paper: CEQL → CEL → CEA → on-the-fly
I/O-determinization → Algorithm 1 over the tECS, with constant update time per
event and output-linear-delay enumeration.
"""
from . import cel
from .cea import CEA, DetCEA, compile_cel
from .ceql import Query, parse
from .engine import Engine, WindowSpec
from .events import ComplexEvent, Event, Valuation, assign_positions
from .partition import PartitionedEngine
from .predicates import (AtomicPredicate, AtomRegistry, PAnd, PAtom, PNot,
                         POr, PredExpr, PTrue)
from .query import CompiledQuery, Executor, compile_query
from .selection import apply_strategy
from .tecs import TECS, enumerate_node

__all__ = [
    "cel", "CEA", "DetCEA", "compile_cel", "Query", "parse", "Engine",
    "WindowSpec", "ComplexEvent", "Event", "Valuation", "assign_positions",
    "PartitionedEngine", "AtomicPredicate", "AtomRegistry", "PAnd", "PAtom",
    "PNot", "POr", "PredExpr", "PTrue", "CompiledQuery", "Executor",
    "compile_query", "apply_strategy", "TECS", "enumerate_node",
]
