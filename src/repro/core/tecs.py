"""timed Enumerable Compact Set (tECS) — paper §5.1–5.2 and Algorithm 2.

A tECS is a DAG with three node kinds:

* **bottom** nodes — labelled with a stream position, no child (the start of an
  open complex event);
* **output** nodes — labelled with a stream position, one child ``next``;
* **union**  nodes — two children ``left``/``right`` with
  ``⟦u⟧ = ⟦left⟧ ∪ ⟦right⟧``.

Invariants maintained by the construction methods (``new_bottom``/``extend``/
``union``/``merge``):

* *time-ordered*: every node caches ``max_start``; for union nodes
  ``max_start(left) ≥ max_start(right)`` — enabling the window prune;
* *3-bounded*: output-depth ≤ 3 everywhere, via the "safe node" discipline
  (safe ⇔ non-union, or odepth(n) = 1 ∧ odepth(right(n)) ≤ 2);
* *duplicate-free*: guaranteed by the caller (I/O-determinism, Theorem 3).

Enumeration (Algorithm 2) is a stack-based DFS that visits left children first
and pushes right children only when their ``max_start`` passes the window
threshold — yielding output-linear delay (Theorem 2).
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Tuple

from .events import ComplexEvent

BOTTOM = 0
OUTPUT = 1
UNION = 2


class Node:
    __slots__ = ("kind", "pos", "max_start", "left", "right")

    def __init__(self, kind: int, pos: int, max_start: int,
                 left: Optional["Node"] = None, right: Optional["Node"] = None):
        self.kind = kind
        self.pos = pos            # stream position (bottom/output only)
        self.max_start = max_start
        self.left = left          # union: left child; output: next
        self.right = right        # union: right child

    # -- structural helpers (used by tests / assertions) ---------------------
    def odepth(self) -> int:
        d, n = 0, self
        while n.kind == UNION:
            d += 1
            n = n.left
        return d

    def is_safe(self) -> bool:
        if self.kind != UNION:
            return True
        return self.odepth() == 1 and (self.right.odepth() <= 2)

    def __repr__(self):  # pragma: no cover
        k = {BOTTOM: "⊥", OUTPUT: "o", UNION: "∨"}[self.kind]
        return f"{k}(pos={self.pos}, max={self.max_start})"


class TECS:
    """The tECS ``E`` plus its construction methods (paper §5.2)."""

    def __init__(self, check_invariants: bool = False):
        self.nodes_created = 0
        self._check = check_invariants

    # -- node constructors ----------------------------------------------------
    def new_bottom(self, i: int) -> Node:
        self.nodes_created += 1
        return Node(BOTTOM, i, i)

    def extend(self, n: Node, j: int) -> Node:
        self.nodes_created += 1
        return Node(OUTPUT, j, n.max_start, left=n)

    def union(self, n1: Node, n2: Node) -> Node:
        """Fig. 5 gadgets (a)–(d).  Requires n1, n2 safe, max(n1) = max(n2)."""
        if self._check:
            assert n1.is_safe() and n2.is_safe()
            assert n1.max_start == n2.max_start
        m = n1.max_start
        self.nodes_created += 1
        if n1.kind != UNION:  # (a)
            return Node(UNION, -1, m, left=n1, right=n2)
        if n2.kind != UNION:  # (b)
            return Node(UNION, -1, m, left=n2, right=n1)
        # both unions: 3 new nodes keep everything time-ordered and 3-bounded
        self.nodes_created += 2
        if n1.right.max_start >= n2.right.max_start:  # (c)
            u2 = Node(UNION, -1, max(n1.right.max_start, n2.right.max_start),
                      left=n1.right, right=n2.right)
            u1 = Node(UNION, -1, m, left=n2.left, right=u2)
            u = Node(UNION, -1, m, left=n1.left, right=u1)
        else:  # (d)
            u2 = Node(UNION, -1, max(n1.right.max_start, n2.right.max_start),
                      left=n2.right, right=n1.right)
            u1 = Node(UNION, -1, m, left=n2.left, right=u2)
            u = Node(UNION, -1, m, left=n1.left, right=u1)
        if self._check:
            assert u.is_safe()
        return u


# ---------------------------------------------------------------------------
# Union-lists (paper §5.2): non-empty sequences n0, n1, ..., nk of safe nodes
# with n0 non-union, max(n0) ≥ max(ni), and max(nj) > max(n_{j+1}) for j ≥ 1.
# ---------------------------------------------------------------------------

UnionList = List[Node]


def new_ulist(n: Node) -> UnionList:
    return [n]


def ulist_insert(tecs: TECS, ul: UnionList, n: Node) -> UnionList:
    """In-place insert of safe node ``n`` with ``max(n) ≤ max(ul[0])``."""
    m = n.max_start
    for i in range(1, len(ul)):
        if ul[i].max_start == m:
            # replace n_i by union(n_i, n) — also updates E
            ul[i] = tecs.union(ul[i], n)
            return ul
        if ul[i].max_start < m:
            ul.insert(i, n)  # keeps positions ≥ 1 strictly decreasing
            return ul
    ul.append(n)  # smallest max-start so far (or max(n) = max(n0), len == 1)
    return ul


def ulist_merge(tecs: TECS, ul: UnionList) -> Node:
    """Fig. 5(e): fold the union-list into one safe node, right-chained."""
    if len(ul) == 1:
        return ul[0]
    acc = ul[-1]
    for i in range(len(ul) - 2, 0, -1):
        tecs.nodes_created += 1
        acc = Node(UNION, -1, ul[i].max_start, left=ul[i], right=acc)
    tecs.nodes_created += 1
    return Node(UNION, -1, ul[0].max_start, left=ul[0], right=acc)


def ulist_max(ul: UnionList) -> int:
    return ul[0].max_start


# ---------------------------------------------------------------------------
# Algorithm 2 — enumeration with output-linear delay.
# ---------------------------------------------------------------------------


def enumerate_arena(kind, pos, max_start, left, right, root: int, j: int,
                    threshold_start: Optional[int] = None,
                    steps: Optional[List[int]] = None
                    ) -> Iterator[ComplexEvent]:
    """Algorithm 2 over a structure-of-arrays tECS (device arena, DESIGN §7).

    Same stack DFS as :func:`enumerate_node`, but nodes are rows of int32
    arrays fetched from the device arena (``kind/pos/max_start/left/right``)
    and ``root`` is an arena index (< 0 = empty).  ``threshold_start`` is the
    window prune (``None`` disables it: arena roots only reference in-window
    nodes, the ring evicts expired starts before they can be shared).
    ``steps``, when given, is a 1-element list incremented once per node
    visit — the work counter the output-linear-delay tests measure.
    """
    if root < 0:
        return
    thr = -(1 << 62) if threshold_start is None else threshold_start
    if max_start[root] < thr:
        return
    stack: List[Tuple[int, Optional[tuple]]] = [(int(root), None)]
    while stack:
        node, plist = stack.pop()
        while True:
            if steps is not None:
                steps[0] += 1
            k = kind[node]
            if k == BOTTOM:
                data = []
                cell = plist
                while cell is not None:
                    data.append(cell[0])
                    cell = cell[1]
                yield ComplexEvent(int(pos[node]), j, tuple(data))
                break
            elif k == OUTPUT:
                plist = (int(pos[node]), plist)
                node = int(left[node])
            else:  # UNION
                r = int(right[node])
                if max_start[r] >= thr:
                    stack.append((r, plist))
                node = int(left[node])


def enumerate_node(n: Node, j: int, threshold_start: int
                   ) -> Iterator[ComplexEvent]:
    """Enumerate ``⟦n⟧ε(j)`` = complex events closed at ``j`` whose start
    position is ``≥ threshold_start`` (i.e. within the window).

    ``threshold_start`` is ``j - ε`` for count-based windows; for time-based
    windows the engine maps the timestamp bound back to the earliest admissible
    start *position* before calling (stream order = time order).
    """
    if n.max_start < threshold_start:
        return
    # Stack entries: (node, reversed linked list of marked positions).  The
    # linked-list representation makes pushing a snapshot O(1) (paper B.1).
    stack: List[Tuple[Node, Optional[tuple]]] = [(n, None)]
    while stack:
        node, plist = stack.pop()
        while True:
            if node.kind == BOTTOM:
                # ⟦p̄⟧ = (i, D): i = pos(bottom); D = labels of the *output*
                # nodes along the full-path (the bottom's own position is the
                # start of the interval, not automatically part of D).
                # The path visits output nodes latest-first and conses each onto
                # the list head, so walking the cons list yields ascending order.
                data = []
                cell = plist
                while cell is not None:
                    data.append(cell[0])
                    cell = cell[1]
                yield ComplexEvent(node.pos, j, tuple(data))
                break
            elif node.kind == OUTPUT:
                plist = (node.pos, plist)
                node = node.left
            else:  # UNION
                if node.right.max_start >= threshold_start:
                    stack.append((node.right, plist))
                node = node.left
