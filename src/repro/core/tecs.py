"""timed Enumerable Compact Set (tECS) — paper §5.1–5.2 and Algorithm 2.

A tECS is a DAG with three node kinds:

* **bottom** nodes — labelled with a stream position, no child (the start of an
  open complex event);
* **output** nodes — labelled with a stream position, one child ``next``;
* **union**  nodes — two children ``left``/``right`` with
  ``⟦u⟧ = ⟦left⟧ ∪ ⟦right⟧``.

Invariants maintained by the construction methods (``new_bottom``/``extend``/
``union``/``merge``):

* *time-ordered*: every node caches ``max_start``; for union nodes
  ``max_start(left) ≥ max_start(right)`` — enabling the window prune;
* *3-bounded*: output-depth ≤ 3 everywhere, via the "safe node" discipline
  (safe ⇔ non-union, or odepth(n) = 1 ∧ odepth(right(n)) ≤ 2);
* *duplicate-free*: guaranteed by the caller (I/O-determinism, Theorem 3).

Enumeration (Algorithm 2) is a stack-based DFS that visits left children first
and pushes right children only when their ``max_start`` passes the window
threshold — yielding output-linear delay (Theorem 2).
"""
from __future__ import annotations

from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .events import ComplexEvent

BOTTOM = 0
OUTPUT = 1
UNION = 2


class Node:
    __slots__ = ("kind", "pos", "max_start", "left", "right")

    def __init__(self, kind: int, pos: int, max_start: int,
                 left: Optional["Node"] = None, right: Optional["Node"] = None):
        self.kind = kind
        self.pos = pos            # stream position (bottom/output only)
        self.max_start = max_start
        self.left = left          # union: left child; output: next
        self.right = right        # union: right child

    # -- structural helpers (used by tests / assertions) ---------------------
    def odepth(self) -> int:
        d, n = 0, self
        while n.kind == UNION:
            d += 1
            n = n.left
        return d

    def is_safe(self) -> bool:
        if self.kind != UNION:
            return True
        return self.odepth() == 1 and (self.right.odepth() <= 2)

    def __repr__(self):  # pragma: no cover
        k = {BOTTOM: "⊥", OUTPUT: "o", UNION: "∨"}[self.kind]
        return f"{k}(pos={self.pos}, max={self.max_start})"


class TECS:
    """The tECS ``E`` plus its construction methods (paper §5.2)."""

    def __init__(self, check_invariants: bool = False):
        self.nodes_created = 0
        self._check = check_invariants

    # -- node constructors ----------------------------------------------------
    def new_bottom(self, i: int) -> Node:
        self.nodes_created += 1
        return Node(BOTTOM, i, i)

    def extend(self, n: Node, j: int) -> Node:
        self.nodes_created += 1
        return Node(OUTPUT, j, n.max_start, left=n)

    def union(self, n1: Node, n2: Node) -> Node:
        """Fig. 5 gadgets (a)–(d).  Requires n1, n2 safe, max(n1) = max(n2)."""
        if self._check:
            assert n1.is_safe() and n2.is_safe()
            assert n1.max_start == n2.max_start
        m = n1.max_start
        self.nodes_created += 1
        if n1.kind != UNION:  # (a)
            return Node(UNION, -1, m, left=n1, right=n2)
        if n2.kind != UNION:  # (b)
            return Node(UNION, -1, m, left=n2, right=n1)
        # both unions: 3 new nodes keep everything time-ordered and 3-bounded
        self.nodes_created += 2
        if n1.right.max_start >= n2.right.max_start:  # (c)
            u2 = Node(UNION, -1, max(n1.right.max_start, n2.right.max_start),
                      left=n1.right, right=n2.right)
            u1 = Node(UNION, -1, m, left=n2.left, right=u2)
            u = Node(UNION, -1, m, left=n1.left, right=u1)
        else:  # (d)
            u2 = Node(UNION, -1, max(n1.right.max_start, n2.right.max_start),
                      left=n2.right, right=n1.right)
            u1 = Node(UNION, -1, m, left=n2.left, right=u2)
            u = Node(UNION, -1, m, left=n1.left, right=u1)
        if self._check:
            assert u.is_safe()
        return u


# ---------------------------------------------------------------------------
# Union-lists (paper §5.2): non-empty sequences n0, n1, ..., nk of safe nodes
# with n0 non-union, max(n0) ≥ max(ni), and max(nj) > max(n_{j+1}) for j ≥ 1.
# ---------------------------------------------------------------------------

UnionList = List[Node]


def new_ulist(n: Node) -> UnionList:
    return [n]


def ulist_insert(tecs: TECS, ul: UnionList, n: Node) -> UnionList:
    """In-place insert of safe node ``n`` with ``max(n) ≤ max(ul[0])``."""
    m = n.max_start
    for i in range(1, len(ul)):
        if ul[i].max_start == m:
            # replace n_i by union(n_i, n) — also updates E
            ul[i] = tecs.union(ul[i], n)
            return ul
        if ul[i].max_start < m:
            ul.insert(i, n)  # keeps positions ≥ 1 strictly decreasing
            return ul
    ul.append(n)  # smallest max-start so far (or max(n) = max(n0), len == 1)
    return ul


def ulist_merge(tecs: TECS, ul: UnionList) -> Node:
    """Fig. 5(e): fold the union-list into one safe node, right-chained."""
    if len(ul) == 1:
        return ul[0]
    acc = ul[-1]
    for i in range(len(ul) - 2, 0, -1):
        tecs.nodes_created += 1
        acc = Node(UNION, -1, ul[i].max_start, left=ul[i], right=acc)
    tecs.nodes_created += 1
    return Node(UNION, -1, ul[0].max_start, left=ul[0], right=acc)


def ulist_max(ul: UnionList) -> int:
    return ul[0].max_start


# ---------------------------------------------------------------------------
# Algorithm 2 — enumeration with output-linear delay.
# ---------------------------------------------------------------------------


def _make_ce(start, end, data, _new=ComplexEvent.__new__) -> ComplexEvent:
    """Hot-path :class:`ComplexEvent` constructor.

    Enumeration materializes one instance per match; the frozen-dataclass
    ``__init__`` costs three ``object.__setattr__`` calls, which dominates
    at high match counts.  Writing ``__dict__`` directly builds the same
    (equal, hashable) instance at a fraction of the cost.
    """
    ce = _new(ComplexEvent)
    d = ce.__dict__
    d["start"] = start
    d["end"] = end
    d["data"] = data
    return ce


def enumerate_arena(kind, pos, max_start, left, right, root: int, j: int,
                    threshold_start: Optional[int] = None,
                    steps: Optional[List[int]] = None
                    ) -> Iterator[ComplexEvent]:
    """Algorithm 2 over a structure-of-arrays tECS (device arena, DESIGN §7).

    Same stack DFS as :func:`enumerate_node`, but nodes are rows of int32
    arrays fetched from the device arena (``kind/pos/max_start/left/right``)
    and ``root`` is an arena index (< 0 = empty).  ``threshold_start`` is the
    window prune (``None`` disables it: arena roots only reference in-window
    nodes, the ring evicts expired starts before they can be shared).
    ``steps``, when given, is a 1-element list incremented once per node
    visit — the work counter the output-linear-delay tests measure.
    """
    if root < 0:
        return
    thr = -(1 << 62) if threshold_start is None else threshold_start
    if max_start[root] < thr:
        return
    stack: List[Tuple[int, Optional[tuple]]] = [(int(root), None)]
    while stack:
        node, plist = stack.pop()
        while True:
            if steps is not None:
                steps[0] += 1
            k = kind[node]
            if k == BOTTOM:
                data = []
                cell = plist
                while cell is not None:
                    data.append(cell[0])
                    cell = cell[1]
                yield ComplexEvent(int(pos[node]), j, tuple(data))
                break
            elif k == OUTPUT:
                plist = (int(pos[node]), plist)
                node = int(left[node])
            else:  # UNION
                r = int(right[node])
                if max_start[r] >= thr:
                    stack.append((r, plist))
                node = int(left[node])


def enumerate_arena_batch(kind, pos, max_start, left, right,
                          roots: Sequence[int], lanes: Sequence[int],
                          ends: Sequence[int], thresholds: Sequence[int],
                          caps: Optional[Sequence[int]] = None,
                          steps: Optional[List[int]] = None
                          ) -> List[List[ComplexEvent]]:
    """Frontier-vectorized Algorithm 2 (DESIGN §13).

    Runs many :func:`enumerate_arena` traversals at once: one root per entry
    of ``roots`` (arena row ids; < 0 = empty), each with its own arena lane
    (``kind``/``pos``/``max_start``/``left``/``right`` are ``(B, capacity)``
    arrays), end position and window threshold.  Instead of a per-node Python
    stack, a *frontier* of pending paths is expanded array-at-a-time: every
    sweep classifies all live rows by node kind, conses output labels into a
    shared pool, and unrolls each union row's whole union-list spine at once
    — the row continues into the list head, and one new row per remaining
    list element (``max_start`` passing the threshold) is inserted after it
    in list order.  Because the expansion is in place and left-first, the
    final order of finished rows is exactly the DFS yield order of
    Algorithm 2, and charging one step per node visit (live rows per sweep
    plus union spine nodes chased through) reproduces the DFS work counter
    — so the output-linear-delay accounting still binds.

    ``caps``, when given, bounds the number of matches kept per root (the
    ``islice`` early-exit of compiled LAST): rows whose finished-match rank
    within their root already reached the cap are pruned every sweep, so work
    stays proportional to the kept output rather than the full match set.
    With a cap the step counter can differ from a lazily-consumed DFS
    generator (the frontier advances breadth-wise past the cap boundary by
    one sweep); without caps the totals are identical.

    Returns one ``list[ComplexEvent]`` per root, each bit-identical (order
    included) to draining the DFS generator.
    """
    n_roots = len(roots)
    out: List[List[ComplexEvent]] = [[] for _ in range(n_roots)]
    if n_roots == 0:
        return out
    # Flattened arena views: 1-D ``take`` gathers are ~2-3x cheaper than 2-D
    # fancy indexing on the small frontiers this walk runs over, and the
    # per-row lane is fixed, so ``lane*capacity + node`` resolves every
    # (lane, node) pair with one fused multiply-add per sweep.
    cap_n = kind.shape[1]
    kind_f = np.ascontiguousarray(kind).reshape(-1)
    pos_f = np.ascontiguousarray(pos).reshape(-1)
    max_start_f = np.ascontiguousarray(max_start).reshape(-1)
    left_f = np.ascontiguousarray(left).reshape(-1)
    right_f = np.ascontiguousarray(right).reshape(-1)
    roots_a = np.asarray(roots, dtype=np.int64)
    lanes_a = np.asarray(lanes, dtype=np.int64)
    thr_a = np.asarray(thresholds, dtype=np.int64)
    caps_a = None if caps is None else np.asarray(caps, dtype=np.int64)
    ok = roots_a >= 0
    safe_root = np.where(ok, roots_a, 0)
    ok &= max_start_f.take(lanes_a * cap_n + safe_root) >= thr_a
    if caps_a is not None:
        ok &= caps_a > 0
    ridx = np.nonzero(ok)[0]
    if ridx.size == 0:
        return out
    # Frontier state (one row per pending DFS path, in DFS yield order).
    node = roots_a[ridx]
    lane = lanes_a[ridx]
    lbase = lane * cap_n
    rthr = thr_a[ridx]
    plist = np.full(ridx.size, -1, dtype=np.int64)   # cons-list head id
    done = np.zeros(ridx.size, dtype=bool)
    start = np.zeros(ridx.size, dtype=np.int64)
    # Shared cons pool (pos, parent) — O(1) amortized append via doubling.
    pp_pos = np.empty(1024, dtype=np.int64)
    pp_par = np.empty(1024, dtype=np.int64)
    pp_len = 0
    n_steps = 0
    while True:
        act = ~done
        n_act = int(act.sum())
        if n_act == 0:
            break
        n_steps += n_act
        fl = lbase + node
        k = np.where(act, kind_f.take(fl), -1)
        is_b = k == BOTTOM
        is_o = k == OUTPUT
        is_u = k == UNION
        if is_o.any():
            flo = fl[is_o]
            n_o = flo.size
            while pp_len + n_o > pp_pos.size:
                pp_pos = np.concatenate([pp_pos, np.empty_like(pp_pos)])
                pp_par = np.concatenate([pp_par, np.empty_like(pp_par)])
            pp_pos[pp_len:pp_len + n_o] = pos_f.take(flo)
            pp_par[pp_len:pp_len + n_o] = plist[is_o]
            plist[is_o] = pp_len + np.arange(n_o)
            pp_len += n_o
            node[is_o] = left_f.take(flo)
        if is_b.any():
            start[is_b] = pos_f.take(fl[is_b])
            done |= is_b
        if is_u.any():
            # Unroll each row's whole union-list spine (the right-chain) in
            # ONE sweep instead of one node per sweep: the row continues
            # into the list head ``left(u)``; chase level ℓ spawns the row
            # for list element ℓ+1 (``left`` of a union spine node, or the
            # chain-tail node itself).  Chasing past a union spine node
            # charges its DFS visit here; non-union spawns are charged when
            # their row is processed.  Spawns insert after the parent in
            # ascending-level order — exactly the order the per-sweep
            # expansion produced, so DFS yield order is preserved.
            ui = np.nonzero(is_u)[0]
            ut = rthr[ui]
            ufl = fl[ui]
            node[ui] = left_f.take(ufl)       # continue into the list head
            lv_rows: List[np.ndarray] = []    # per level: local ids into ui
            lv_nodes: List[np.ndarray] = []
            al = np.arange(ui.size)           # rows still on the spine
            ab = lbase[ui]                    # their lane*capacity bases
            athr = ut
            afl = ufl
            lv = 0
            while al.size:
                if lv:
                    # every row entering level >= 1 got here by chasing
                    # through a union spine node — charge its DFS visit
                    # (equals the per-level ru count without a sum sync)
                    n_steps += al.size
                lv += 1
                r = right_f.take(afl)
                rfl = ab + r
                ex = max_start_f.take(rfl) >= athr
                al = al[ex]
                if al.size == 0:
                    break
                r, rfl, ab, athr = r[ex], rfl[ex], ab[ex], athr[ex]
                ru = kind_f.take(rfl) == UNION
                lv_rows.append(al)
                lv_nodes.append(np.where(
                    ru, left_f.take(np.where(ru, rfl, 0)), r))
                al, ab, athr, afl = al[ru], ab[ru], athr[ru], rfl[ru]
            if lv_rows:
                # A row stays on the spine through consecutive chase levels,
                # so its spawn at level l has within-parent rank exactly l —
                # all levels scatter into the rebuilt frontier in ONE pass.
                n_sp = np.zeros(ui.size, dtype=np.int64)
                for lr in lv_rows:
                    n_sp[lr] += 1
                cnt = np.ones(node.size, dtype=np.int64)
                cnt[ui] += n_sp
                offs = np.cumsum(cnt) - cnt
                total = int(offs[-1] + cnt[-1])
                src = np.concatenate([ui[lr] for lr in lv_rows])
                at = np.concatenate([offs[ui[lr]] + 1 + lv
                                     for lv, lr in enumerate(lv_rows)])
                nodes_cat = np.concatenate(lv_nodes)
                new = {}
                for name, arr in (("node", node), ("lane", lane),
                                  ("rthr", rthr), ("plist", plist),
                                  ("ridx", ridx), ("start", start),
                                  ("done", done)):
                    na = np.empty(total, dtype=arr.dtype)
                    na[offs] = arr
                    na[at] = nodes_cat if name == "node" else (
                        False if name == "done" else arr[src])
                    new[name] = na
                node, lane, rthr, plist, ridx, start, done = (
                    new["node"], new["lane"], new["rthr"], new["plist"],
                    new["ridx"], new["start"], new["done"])
                lbase = lane * cap_n
        if caps_a is not None:
            # Prune rows whose match rank within their root already reached
            # the cap — they can only produce matches past the islice cutoff.
            done_excl = np.cumsum(done) - done
            seg_first = np.searchsorted(ridx, ridx)   # rows sorted by ridx
            rank = done_excl - done_excl[seg_first]
            keep = rank < caps_a[ridx]
            if not keep.all():
                node, lane, rthr, plist, ridx, start, done = (
                    node[keep], lane[keep], rthr[keep], plist[keep],
                    ridx[keep], start[keep], done[keep])
                lbase = lbase[keep]
    if steps is not None:
        steps[0] += n_steps
    if done.size == 0:
        return out
    # Reconstruct data tuples: walking a cons list from its head yields the
    # marked positions in ascending order (deepest output consed last), so
    # column i of the gather matrix is element i of each tuple.
    ends_l = [int(e) for e in ends]
    cur = plist.copy()
    cols = []
    while True:
        valid = cur >= 0
        if not valid.any():
            break
        safe = np.where(valid, cur, 0)
        cols.append(np.where(valid, pp_pos[safe], -1))
        cur = np.where(valid, pp_par[safe], -1)
    starts_l = start.tolist()
    ends_row = np.asarray(ends_l, dtype=np.int64)[ridx].tolist()
    mk = _make_ce
    if not cols:
        ces = list(map(mk, starts_l, ends_row, ((),) * len(starts_l)))
    elif bool((cols[-1] >= 0).all()):
        # homogeneous data sizes (padding appears only in trailing
        # columns): zip(*) conses every data tuple at C speed
        ces = list(map(mk, starts_l, ends_row,
                       zip(*[c.tolist() for c in cols])))
    else:
        mat = np.stack(cols, axis=1)
        lens_l = (mat >= 0).sum(axis=1).tolist()
        ces = [mk(s, e, tuple(row[:n])) for s, e, row, n in
               zip(starts_l, ends_row, mat.tolist(), lens_l)]
    # Rows of one root stay contiguous (spawns insert next to their parent)
    # and roots in input order, so ridx is non-decreasing: split by
    # boundaries instead of appending row by row.
    if np.all(ridx[:-1] <= ridx[1:]):
        bounds = np.searchsorted(ridx, np.arange(n_roots + 1)).tolist()
        for ri in range(n_roots):
            lo, hi = bounds[ri], bounds[ri + 1]
            if lo != hi:
                out[ri] = ces[lo:hi]
    else:  # pragma: no cover — defensive; insertion order keeps ridx sorted
        for ri, ce in zip(ridx.tolist(), ces):
            out[ri].append(ce)
    if caps_a is not None:
        for ri in set(ridx.tolist()):
            out[ri] = out[ri][:int(caps_a[ri])]
    return out


def enumerate_node(n: Node, j: int, threshold_start: int
                   ) -> Iterator[ComplexEvent]:
    """Enumerate ``⟦n⟧ε(j)`` = complex events closed at ``j`` whose start
    position is ``≥ threshold_start`` (i.e. within the window).

    ``threshold_start`` is ``j - ε`` for count-based windows; for time-based
    windows the engine maps the timestamp bound back to the earliest admissible
    start *position* before calling (stream order = time order).
    """
    if n.max_start < threshold_start:
        return
    # Stack entries: (node, reversed linked list of marked positions).  The
    # linked-list representation makes pushing a snapshot O(1) (paper B.1).
    stack: List[Tuple[Node, Optional[tuple]]] = [(n, None)]
    while stack:
        node, plist = stack.pop()
        while True:
            if node.kind == BOTTOM:
                # ⟦p̄⟧ = (i, D): i = pos(bottom); D = labels of the *output*
                # nodes along the full-path (the bottom's own position is the
                # start of the interval, not automatically part of D).
                # The path visits output nodes latest-first and conses each onto
                # the list head, so walking the cons list yields ascending order.
                data = []
                cell = plist
                while cell is not None:
                    data.append(cell[0])
                    cell = cell[1]
                yield ComplexEvent(node.pos, j, tuple(data))
                break
            elif node.kind == OUTPUT:
                plist = (node.pos, plist)
                node = node.left
            else:  # UNION
                if node.right.max_start >= threshold_start:
                    stack.append((node.right, plist))
                node = node.left
