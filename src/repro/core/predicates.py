"""Predicates and the bit-vector tuple representation (paper §3, §5.4).

A (unary) predicate is a set of data-tuples.  CORE collects all *atomic*
predicates of a query into an indexed list ``P_1..P_k`` and represents each
incoming tuple ``t`` as the bit-vector ``v_t`` with ``v_t[i] = 1  iff  t ⊨ P_i``.
Every transition predicate of the compiled CEA is then a boolean formula over
bit indices (a :class:`BitExpr`), so it is evaluated on the bit-vector alone —
each attribute comparison is computed exactly once per tuple (paper §5.4).
"""
from __future__ import annotations

import operator
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .events import NULL, Event

# ---------------------------------------------------------------------------
# Attribute-level atomic predicates
# ---------------------------------------------------------------------------

_OPS: Dict[str, Callable[[Any, Any], bool]] = {
    "==": operator.eq,
    "!=": operator.ne,
    "<": operator.lt,
    "<=": operator.le,
    ">": operator.gt,
    ">=": operator.ge,
}


@dataclass(frozen=True)
class AtomicPredicate:
    """``t[attr] <op> constant`` — or a type test when ``attr == 'type'``."""

    attr: str
    op: str
    value: Any

    def evaluate(self, t: Event) -> bool:
        lhs = t.get(self.attr)
        if lhs is NULL:
            return False
        try:
            return _OPS[self.op](lhs, self.value)
        except TypeError:
            return False

    def __str__(self) -> str:
        return f"{self.attr}{self.op}{self.value!r}"


def type_predicate(event_type: str) -> AtomicPredicate:
    """``P_R := {t | t(type) = R}`` (paper Fig. 10)."""
    return AtomicPredicate("type", "==", event_type)


# ---------------------------------------------------------------------------
# Attribute-level predicate formulas (used by FILTER before CEA compilation)
# ---------------------------------------------------------------------------


class PredExpr:
    """Boolean formula over :class:`AtomicPredicate` leaves."""

    def evaluate(self, t: Event) -> bool:
        raise NotImplementedError

    def atoms(self) -> List[AtomicPredicate]:
        raise NotImplementedError


@dataclass(frozen=True)
class PAtom(PredExpr):
    atom: AtomicPredicate

    def evaluate(self, t):
        return self.atom.evaluate(t)

    def atoms(self):
        return [self.atom]


@dataclass(frozen=True)
class PAnd(PredExpr):
    left: PredExpr
    right: PredExpr

    def evaluate(self, t):
        return self.left.evaluate(t) and self.right.evaluate(t)

    def atoms(self):
        return self.left.atoms() + self.right.atoms()


@dataclass(frozen=True)
class POr(PredExpr):
    left: PredExpr
    right: PredExpr

    def evaluate(self, t):
        return self.left.evaluate(t) or self.right.evaluate(t)

    def atoms(self):
        return self.left.atoms() + self.right.atoms()


@dataclass(frozen=True)
class PNot(PredExpr):
    child: PredExpr

    def evaluate(self, t):
        return not self.child.evaluate(t)

    def atoms(self):
        return self.child.atoms()


@dataclass(frozen=True)
class PTrue(PredExpr):
    def evaluate(self, t):
        return True

    def atoms(self):
        return []


# ---------------------------------------------------------------------------
# Bit-level formulas (transition predicates after atom indexing)
# ---------------------------------------------------------------------------


class BitExpr:
    """Boolean formula over bit positions of the query's bit-vector."""

    def evaluate(self, bitvec: int) -> bool:
        raise NotImplementedError


@dataclass(frozen=True)
class BTrue(BitExpr):
    def evaluate(self, bitvec: int) -> bool:
        return True

    def __str__(self):
        return "TRUE"


@dataclass(frozen=True)
class BLit(BitExpr):
    bit: int
    positive: bool = True

    def evaluate(self, bitvec: int) -> bool:
        val = bool((bitvec >> self.bit) & 1)
        return val if self.positive else not val

    def __str__(self):
        return f"b{self.bit}" if self.positive else f"!b{self.bit}"


@dataclass(frozen=True)
class BAnd(BitExpr):
    left: BitExpr
    right: BitExpr

    def evaluate(self, bitvec: int) -> bool:
        return self.left.evaluate(bitvec) and self.right.evaluate(bitvec)

    def __str__(self):
        return f"({self.left} & {self.right})"


@dataclass(frozen=True)
class BOr(BitExpr):
    left: BitExpr
    right: BitExpr

    def evaluate(self, bitvec: int) -> bool:
        return self.left.evaluate(bitvec) or self.right.evaluate(bitvec)

    def __str__(self):
        return f"({self.left} | {self.right})"


@dataclass(frozen=True)
class BNot(BitExpr):
    child: BitExpr

    def evaluate(self, bitvec: int) -> bool:
        return not self.child.evaluate(bitvec)

    def __str__(self):
        return f"!{self.child}"


# ---------------------------------------------------------------------------
# Atom registry: assigns bit indices and evaluates whole tuples to bit-vectors
# ---------------------------------------------------------------------------


class AtomRegistry:
    """Indexes the distinct atomic predicates of a query (paper §5.4).

    ``bitvector(t)`` evaluates each atomic predicate exactly once for tuple
    ``t`` and returns the packed integer bit-vector used as the tuple's internal
    representation by both the host engine and the device engine.
    """

    def __init__(self) -> None:
        self._atoms: List[AtomicPredicate] = []
        self._index: Dict[AtomicPredicate, int] = {}

    def register(self, atom: AtomicPredicate) -> int:
        idx = self._index.get(atom)
        if idx is None:
            idx = len(self._atoms)
            self._atoms.append(atom)
            self._index[atom] = idx
        return idx

    def lower(self, expr: PredExpr) -> BitExpr:
        """Rewrite an attribute-level formula into a bit-level formula."""
        if isinstance(expr, PTrue):
            return BTrue()
        if isinstance(expr, PAtom):
            return BLit(self.register(expr.atom))
        if isinstance(expr, PAnd):
            return BAnd(self.lower(expr.left), self.lower(expr.right))
        if isinstance(expr, POr):
            return BOr(self.lower(expr.left), self.lower(expr.right))
        if isinstance(expr, PNot):
            return BNot(self.lower(expr.child))
        raise TypeError(f"unknown predicate expression {expr!r}")

    @property
    def atoms(self) -> Sequence[AtomicPredicate]:
        return tuple(self._atoms)

    @property
    def num_bits(self) -> int:
        return len(self._atoms)

    def bitvector(self, t: Event) -> int:
        v = 0
        for i, atom in enumerate(self._atoms):
            if atom.evaluate(t):
                v |= 1 << i
        return v

    def specs(self) -> List[Tuple[str, str, Any]]:
        """(attr, op, value) triples — consumed by the device bit-vector kernel."""
        return [(a.attr, a.op, a.value) for a in self._atoms]
