"""Complex Event Logic (CEL) abstract syntax and direct semantics (paper §3).

The grammar (paper §3):

    φ := R | φ AS X | φ FILTER X[P] | φ OR φ | φ ; φ | φ+ | π_L(φ)

``semantics(φ, stream)`` implements Table 2 *directly* (sets of valuations) and
is used as the brute-force oracle against which the automaton engine is tested.
It is exponential and only suitable for tiny streams.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, Iterable, List, Sequence, Set, Tuple

from .events import Event, Valuation
from .predicates import PredExpr


class CEL:
    """Base class for CEL formulas."""

    # convenience combinators -------------------------------------------------
    def seq(self, other: "CEL") -> "CEL":
        return Seq(self, other)

    def or_(self, other: "CEL") -> "CEL":
        return Or(self, other)

    def plus(self) -> "CEL":
        return Plus(self)

    def as_(self, var: str) -> "CEL":
        return As(self, var)

    def filter(self, var: str, pred: PredExpr) -> "CEL":
        return Filter(self, var, pred)

    def variables(self) -> Set[str]:
        raise NotImplementedError


@dataclass(frozen=True)
class EventType(CEL):
    name: str

    def variables(self):
        return {self.name}

    def __str__(self):
        return self.name


@dataclass(frozen=True)
class As(CEL):
    child: CEL
    var: str

    def variables(self):
        return self.child.variables() | {self.var}

    def __str__(self):
        return f"({self.child} AS {self.var})"


@dataclass(frozen=True)
class Filter(CEL):
    child: CEL
    var: str
    pred: PredExpr

    def variables(self):
        return self.child.variables()

    def __str__(self):
        return f"({self.child} FILTER {self.var}[{self.pred}])"


@dataclass(frozen=True)
class Or(CEL):
    left: CEL
    right: CEL

    def variables(self):
        return self.left.variables() | self.right.variables()

    def __str__(self):
        return f"({self.left} OR {self.right})"


@dataclass(frozen=True)
class Seq(CEL):
    left: CEL
    right: CEL

    def variables(self):
        return self.left.variables() | self.right.variables()

    def __str__(self):
        return f"({self.left} ; {self.right})"


@dataclass(frozen=True)
class Plus(CEL):
    child: CEL

    def variables(self):
        return self.child.variables()

    def __str__(self):
        return f"({self.child})+"


@dataclass(frozen=True)
class Proj(CEL):
    child: CEL
    keep: FrozenSet[str]

    def variables(self):
        return set(self.keep)

    def __str__(self):
        return f"π_{{{','.join(sorted(self.keep))}}}({self.child})"


# ---------------------------------------------------------------------------
# Direct (oracle) semantics — Table 2 of the paper.
# Valuations are represented as (start, end, {var: frozenset(positions)}).
# ---------------------------------------------------------------------------

_Val = Tuple[int, int, Tuple[Tuple[str, FrozenSet[int]], ...]]


def _mk(mapping: dict) -> Tuple[Tuple[str, FrozenSet[int]], ...]:
    return tuple(sorted((k, frozenset(v)) for k, v in mapping.items() if v))


def _to_dict(mapping: Tuple[Tuple[str, FrozenSet[int]], ...]) -> dict:
    return {k: set(v) for k, v in mapping}


def semantics(phi: CEL, stream: Sequence[Event]) -> Set[_Val]:
    """``⟦φ⟧(S)`` — the set of valuations of φ over (a finite prefix of) S."""
    if isinstance(phi, EventType):
        out = set()
        for i, t in enumerate(stream):
            if t.type == phi.name:
                out.add((i, i, _mk({phi.name: {i}})))
        return out
    if isinstance(phi, As):
        out = set()
        for (i, j, mu) in semantics(phi.child, stream):
            d = _to_dict(mu)
            gathered = set()
            for positions in d.values():
                gathered |= positions
            d[phi.var] = d.get(phi.var, set()) | gathered
            out.add((i, j, _mk(d)))
        return out
    if isinstance(phi, Filter):
        out = set()
        for (i, j, mu) in semantics(phi.child, stream):
            d = _to_dict(mu)
            positions = d.get(phi.var, set())
            if all(phi.pred.evaluate(stream[p]) for p in positions):
                out.add((i, j, mu))
        return out
    if isinstance(phi, Or):
        return semantics(phi.left, stream) | semantics(phi.right, stream)
    if isinstance(phi, Seq):
        lefts = semantics(phi.left, stream)
        rights = semantics(phi.right, stream)
        out = set()
        for (i1, j1, mu1) in lefts:
            for (i2, j2, mu2) in rights:
                if j1 < i2:  # V1(end) < V2(start)
                    d = _to_dict(mu1)
                    d2 = _to_dict(mu2)
                    for k, v in d2.items():
                        d[k] = d.get(k, set()) | v
                    out.add((i1, j2, _mk(d)))
        return out
    if isinstance(phi, Plus):
        base = semantics(phi.child, stream)
        out = set(base)
        frontier = set(base)
        # fixpoint: φ+ = φ OR (φ+ ; φ)
        while frontier:
            new = set()
            for (i1, j1, mu1) in frontier:
                for (i2, j2, mu2) in base:
                    if j1 < i2:
                        d = _to_dict(mu1)
                        d2 = _to_dict(mu2)
                        for k, v in d2.items():
                            d[k] = d.get(k, set()) | v
                        cand = (i1, j2, _mk(d))
                        if cand not in out:
                            new.add(cand)
            out |= new
            frontier = new
        return out
    if isinstance(phi, Proj):
        out = set()
        for (i, j, mu) in semantics(phi.child, stream):
            d = {k: v for k, v in _to_dict(mu).items() if k in phi.keep}
            out.add((i, j, _mk(d)))
        return out
    raise TypeError(f"unknown CEL node {phi!r}")


def complex_events(phi: CEL, stream: Sequence[Event], epsilon=None) -> Set[Tuple[int, int, Tuple[int, ...]]]:
    """``⟦φ⟧(S)`` under the complex-event semantics, optionally windowed."""
    out = set()
    for (i, j, mu) in semantics(phi, stream):
        if epsilon is not None and j - i > epsilon:
            continue
        data = set()
        for _, positions in mu:
            data |= positions
        out.add((i, j, tuple(sorted(data))))
    return out
