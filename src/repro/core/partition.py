"""PARTITION BY evaluation (paper §3 semantics, §5.4 implementation).

The stream is logically split into maximal substreams that agree (and are
non-NULL) on every partition attribute; WHERE-SELECT-WITHIN runs on each
substream separately.  CORE implements this by hashing the attribute values
and running one engine instance per partition — here a dict of engines.

Each partition engine evaluates over its substream with *local* positions
(count-based windows therefore count events of the substream, matching the
"executes WHERE-SELECT-WITHIN on each substream separately" semantics); the
returned complex events are relabelled back to global stream positions.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, Iterable, List, Optional, Tuple

from .events import ComplexEvent, Event, NULL

# Device-side key sentinels (vector/partitioned.py).  Partition-key hashes
# are clamped below EMPTY_LANE so real keys can never collide with either.
NULL_KEY_HASH = 0xFFFFFFFF   # tuple is NULL on a partition attribute → drop
EMPTY_LANE = 0xFFFFFFFE      # lane-table slot owned by no partition


def partition_key(t: Event, attrs: Tuple[str, ...]) -> Optional[tuple]:
    """The tuple of partition-attribute values, or None for NULL keys.

    Paper §3: tuples NULL on any partition attribute join no substream —
    both the host dict-of-engines and the device lane router drop them.
    """
    key = tuple(t.get(a) for a in attrs)
    if any(v is NULL for v in key):
        return None
    return key


def stable_key_hash(key: Optional[tuple]) -> int:
    """Deterministic 32-bit FNV-1a hash of a partition key.

    Python's ``hash()`` is salted per process for strings, so it cannot be
    the routing hash (restarts would re-shuffle partitions).  Numeric values
    are canonicalized the way Python dict keys compare (``1 == 1.0 == True``
    land in one partition), matching the host ``PartitionedEngine``'s dict
    semantics.  Hashes ≥ EMPTY_LANE are folded down so sentinels stay
    unreachable.
    """
    if key is None:
        return NULL_KEY_HASH
    h = 0x811C9DC5
    for v in key:
        if isinstance(v, str):
            data = b"s" + v.encode("utf-8")
        elif isinstance(v, (bool, int)) or hasattr(v, "__index__"):
            # exact integer canonical form (also numpy integer scalars via
            # __index__) — never via float, which would collapse distinct
            # ints ≥ 2⁵³ and overflow on huge ints
            data = b"i" + str(int(v)).encode()
        elif isinstance(v, float) or hasattr(v, "is_integer"):
            # floats incl. numpy floating scalars: integral values share the
            # exact-int form (dict semantics: 1 == 1.0 == np.float32(1.0))
            f = float(v)
            data = (b"i" + str(int(f)).encode() if f.is_integer()
                    else b"f" + repr(f).encode())
        else:
            data = b"o" + repr(v).encode()
        for byte in data:
            h = ((h ^ byte) * 0x01000193) & 0xFFFFFFFF
        h = ((h ^ 0xAA) * 0x01000193) & 0xFFFFFFFF   # component separator
    return h if h < EMPTY_LANE else h - 2


class PartitionedEngine:
    def __init__(self, make_engine: Callable[[], "object"],
                 attrs: Tuple[str, ...]):
        self.make_engine = make_engine
        self.attrs = attrs
        self.partitions: Dict[Hashable, object] = {}
        self.j = -1

    def process(self, t: Event) -> List[ComplexEvent]:
        self.j += 1
        key = partition_key(t, self.attrs)
        if key is None:
            return []  # tuples NULL on a partition attribute join no substream
        eng = self.partitions.get(key)
        if eng is None:
            eng = self.make_engine()
            self.partitions[key] = eng
        # Each partition engine sees only its substream; positions inside the
        # engine are per-substream, and we relabel outputs to global positions.
        pos_map = getattr(eng, "_global_positions", None)
        if pos_map is None:
            pos_map = []
            eng._global_positions = pos_map
        pos_map.append(self.j)
        out = eng.process(t)
        return [ComplexEvent(pos_map[c.start], pos_map[c.end],
                             tuple(pos_map[p] for p in c.data))
                for c in out]

    @property
    def num_partitions(self) -> int:
        return len(self.partitions)
