"""PARTITION BY evaluation (paper §3 semantics, §5.4 implementation).

The stream is logically split into maximal substreams that agree (and are
non-NULL) on every partition attribute; WHERE-SELECT-WITHIN runs on each
substream separately.  CORE implements this by hashing the attribute values
and running one engine instance per partition — here a dict of engines.

Each partition engine evaluates over its substream with *local* positions
(count-based windows therefore count events of the substream, matching the
"executes WHERE-SELECT-WITHIN on each substream separately" semantics); the
returned complex events are relabelled back to global stream positions.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, Iterable, List, Optional, Tuple

from .events import ComplexEvent, Event, NULL


class PartitionedEngine:
    def __init__(self, make_engine: Callable[[], "object"],
                 attrs: Tuple[str, ...]):
        self.make_engine = make_engine
        self.attrs = attrs
        self.partitions: Dict[Hashable, object] = {}
        self.j = -1

    def process(self, t: Event) -> List[ComplexEvent]:
        self.j += 1
        key = tuple(t.get(a) for a in self.attrs)
        if any(v is NULL for v in key):
            return []  # tuples NULL on a partition attribute join no substream
        eng = self.partitions.get(key)
        if eng is None:
            eng = self.make_engine()
            self.partitions[key] = eng
        # Each partition engine sees only its substream; positions inside the
        # engine are per-substream, and we relabel outputs to global positions.
        pos_map = getattr(eng, "_global_positions", None)
        if pos_map is None:
            pos_map = []
            eng._global_positions = pos_map
        pos_map.append(self.j)
        out = eng.process(t)
        return [ComplexEvent(pos_map[c.start], pos_map[c.end],
                             tuple(pos_map[p] for p in c.data))
                for c in out]

    @property
    def num_partitions(self) -> int:
        return len(self.partitions)
