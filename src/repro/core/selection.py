"""Selection strategies (paper §2, §6 'Selection strategies'; semantics in [31]).

CORE supports ALL (the default skip-till-any-match), NXT, LAST and MAX.  The
paper implements these at the automaton level via a strategy-aware
determinization.  Here ALL is automaton-level (identical algorithm); NXT, LAST
and MAX are *result-level reducers* applied to the per-position output set —
observably equivalent (design deviation D2 in DESIGN.md), since a selection
strategy is by definition a subset selector of the matched complex events.

Definitions used (per position j, over the set M_j of matches ending at j):

* ``MAX``  — keep C ∈ M_j iff no C' ∈ M_j with same interval start and
  C.data ⊊ C'.data (maximal sequences; the paper's Q3 segmentation use-case).
* ``LAST`` — keep the matches with the latest start; ties broken by keeping
  maximal data sets.
* ``NXT``  — keep, per start position, the lexicographically earliest data set
  (the "next"/earliest-match heuristic).
"""
from __future__ import annotations

from typing import Dict, List

from .events import ComplexEvent


def apply_strategy(strategy: str, matches: List[ComplexEvent]) -> List[ComplexEvent]:
    if strategy in ("ALL", "ANY") or not matches:
        return matches
    if strategy == "MAX":
        out = []
        for c in matches:
            dominated = any(
                c2 is not c and c2.start == c.start and
                set(c.data) < set(c2.data)
                for c2 in matches)
            if not dominated:
                out.append(c)
        return out
    if strategy == "LAST":
        best = max(c.start for c in matches)
        latest = [c for c in matches if c.start == best]
        return apply_strategy("MAX", latest)
    if strategy in ("NXT", "NEXT"):
        per_start: Dict[int, ComplexEvent] = {}
        for c in matches:
            cur = per_start.get(c.start)
            if cur is None or c.data < cur.data:
                per_start[c.start] = c
        return [per_start[k] for k in sorted(per_start)]
    if strategy == "STRICT":
        # strict contiguity: every position in [start, end] is in data
        return [c for c in matches
                if len(c.data) == c.end - c.start + 1]
    raise ValueError(f"unknown selection strategy {strategy!r}")
