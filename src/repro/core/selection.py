"""Selection strategies (paper §2, §6 'Selection strategies'; semantics in [31]).

CORE supports ALL (the default skip-till-any-match), NXT, LAST, MAX and — in
this repo's dialect — STRICT (contiguous matches only).  The paper implements
these at the automaton level via a strategy-aware determinization; the device
engines now do the same (``compile_symbolic(cea, strategy=…)``, DESIGN.md D2).
The reducers in this module are the *host oracle*: result-level subset
selectors applied to the per-position output set, used by the host
``Executor``, by ALL-compiled engines asked to post-filter at enumeration
time, and by the parity tests that pin the device tables to these semantics.

Definitions used (per position j, over the set M_j of matches ending at j):

* ``MAX``    — keep C ∈ M_j iff no C' ∈ M_j with same interval start and
  C.data ⊊ C'.data (maximal sequences; the paper's Q3 segmentation use-case).
* ``LAST``   — keep the matches with the latest start; ties broken by keeping
  maximal data sets.
* ``NXT``    — keep, per start position, the lexicographically earliest data
  set (the "next"/earliest-match heuristic).
* ``STRICT`` — keep C ∈ M_j iff its data set covers every position of its
  interval (``len(data) == end - start + 1``: strict contiguity).

The reducers operate on *enumerated* results — host tECS or device-arena
alike (ComplexEvents from :meth:`ArenaSnapshot.enumerate` carry plain-int
positions and arrive in DFS order, which none of the reducers depend on).
Strategies are defined per position ``j`` over the set ``M_j`` of matches
closing at ``j``: use :func:`apply_strategy_per_position` for a flat list
spanning several positions (e.g. all hits of a streamed chunk) — applying
``LAST``/``NXT`` across positions would silently compare unrelated ``M_j``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

from .events import ComplexEvent

STRATEGIES = ("ALL", "ANY", "MAX", "LAST", "NXT", "NEXT", "STRICT")


def apply_strategy(strategy: str, matches: Iterable[ComplexEvent]
                   ) -> List[ComplexEvent]:
    """Reduce the matches of ONE closing position under ``strategy``."""
    if strategy not in STRATEGIES:
        # Validate before the empty-list early return: a bogus strategy name
        # must raise even when there is nothing to filter.
        raise ValueError(f"unknown selection strategy {strategy!r}")
    matches = list(matches)
    if strategy in ("ALL", "ANY") or not matches:
        return matches
    if strategy == "MAX":
        out = []
        for c in matches:
            dominated = any(
                c2 is not c and c2.start == c.start and
                set(c.data) < set(c2.data)
                for c2 in matches)
            if not dominated:
                out.append(c)
        return out
    if strategy == "LAST":
        best = max(c.start for c in matches)
        latest = [c for c in matches if c.start == best]
        return apply_strategy("MAX", latest)
    if strategy in ("NXT", "NEXT"):
        per_start: Dict[int, ComplexEvent] = {}
        for c in matches:
            cur = per_start.get(c.start)
            if cur is None or c.data < cur.data:
                per_start[c.start] = c
        return [per_start[k] for k in sorted(per_start)]
    # strategy == "STRICT": strict contiguity — every position in
    # [start, end] is in data
    return [c for c in matches
            if len(c.data) == c.end - c.start + 1]


def apply_strategy_per_position(strategy: str,
                                matches: Iterable[ComplexEvent]
                                ) -> List[ComplexEvent]:
    """Reduce a flat enumerated list position-by-position.

    Selection strategies (ALL/ANY, MAX, LAST, NXT, STRICT — see the module
    docstring) are subset selectors of ``M_j`` — the matches closing at one
    position ``j``.  A chunk's enumerated arena results span many positions;
    this groups them by ``end`` and reduces each group independently,
    returning groups in ascending position order.
    """
    groups: Dict[int, List[ComplexEvent]] = {}
    for c in matches:
        groups.setdefault(int(c.end), []).append(c)
    out: List[ComplexEvent] = []
    for j in sorted(groups):
        out.extend(apply_strategy(strategy, groups[j]))
    return out
