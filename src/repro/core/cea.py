"""Complex Event Automata (paper §4, Appendix A).

Pipeline:

    CEL formula ──compile──▶ VCEA (variable-marking transitions, Appendix A)
               ──project──▶ CEA  (•/◦ marking actions, single initial state)
               ──on-the-fly subset construction──▶ I/O-deterministic CEA view

The determinization is performed lazily while the stream is processed and its
results are cached (``(det-state, bit-vector) → (q•, q◦)``), exactly as §5.4
describes.  Det states are frozensets of CEA states; the cache is the paper's
"fast-index".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from . import cel as C
from .predicates import (AtomRegistry, BAnd, BitExpr, BLit, BTrue, PredExpr,
                         PTrue, type_predicate, PAtom)

# ---------------------------------------------------------------------------
# VCEA — valuation CEA (Appendix A)
# ---------------------------------------------------------------------------

Label = FrozenSet[str]


@dataclass
class VTransition:
    src: int
    pred: BitExpr
    label: Label  # ∅ ⇒ non-marking
    dst: int


@dataclass
class VCEA:
    num_states: int
    transitions: List[VTransition]
    initial: Set[int]
    finals: Set[int]


class _Builder:
    """Fresh-state allocator shared across the inductive construction."""

    def __init__(self, registry: AtomRegistry):
        self.registry = registry
        self.count = 0

    def fresh(self) -> int:
        s = self.count
        self.count += 1
        return s


def _compile(phi: C.CEL, b: _Builder) -> VCEA:
    if isinstance(phi, C.EventType):
        q1, q2 = b.fresh(), b.fresh()
        bit = b.registry.register(type_predicate(phi.name))
        tr = VTransition(q1, BLit(bit), frozenset({phi.name}), q2)
        return VCEA(b.count, [tr], {q1}, {q2})

    if isinstance(phi, C.As):
        a = _compile(phi.child, b)
        out = []
        for t in a.transitions:
            if t.label:
                out.append(VTransition(t.src, t.pred, t.label | {phi.var}, t.dst))
            else:
                out.append(t)
        return VCEA(b.count, out, a.initial, a.finals)

    if isinstance(phi, C.Filter):
        a = _compile(phi.child, b)
        pbit = b.registry.lower(phi.pred)
        out = []
        for t in a.transitions:
            if phi.var in t.label:
                out.append(VTransition(t.src, BAnd(t.pred, pbit), t.label, t.dst))
            else:
                out.append(t)
        return VCEA(b.count, out, a.initial, a.finals)

    if isinstance(phi, C.Or):
        a1 = _compile(phi.left, b)
        a2 = _compile(phi.right, b)
        return VCEA(b.count, a1.transitions + a2.transitions,
                    a1.initial | a2.initial, a1.finals | a2.finals)

    if isinstance(phi, C.Seq):
        a1 = _compile(phi.left, b)
        a2 = _compile(phi.right, b)
        out = a1.transitions + a2.transitions
        # skip self-loops on the initial states of the second operand
        for p in a2.initial:
            out.append(VTransition(p, BTrue(), frozenset(), p))
        # bridge: transitions into F1 are copied to go into I2
        for t in a1.transitions:
            if t.dst in a1.finals:
                for q in a2.initial:
                    out.append(VTransition(t.src, t.pred, t.label, q))
        return VCEA(b.count, out, a1.initial, a2.finals)

    if isinstance(phi, C.Plus):
        a = _compile(phi.child, b)
        q = b.fresh()
        out = list(a.transitions)
        # finishing one iteration lands on the junction q ...
        for t in a.transitions:
            if t.dst in a.finals:
                out.append(VTransition(t.src, t.pred, t.label, q))
        # ... from which the next iteration can start ...
        for t in a.transitions:
            if t.src in a.initial:
                out.append(VTransition(q, t.pred, t.label, t.dst))
        # ... and a one-transition iteration goes junction → junction (needed
        # from the third iteration onward when the body is a single step).
        for t in a.transitions:
            if t.src in a.initial and t.dst in a.finals:
                out.append(VTransition(q, t.pred, t.label, q))
        # Skip-till-any-match between iterations: φ+ ≡ φ OR (φ ; φ+), and the
        # ';' construction introduces a TRUE self-loop before the second
        # operand.  The junction state therefore carries the same self-loop.
        out.append(VTransition(q, BTrue(), frozenset(), q))
        return VCEA(b.count, out, a.initial, a.finals)

    if isinstance(phi, C.Proj):
        a = _compile(phi.child, b)
        out = [VTransition(t.src, t.pred, frozenset(t.label & phi.keep), t.dst)
               for t in a.transitions]
        return VCEA(b.count, out, a.initial, a.finals)

    raise TypeError(f"unknown CEL node {phi!r}")


# ---------------------------------------------------------------------------
# CEA — single initial state, •/◦ actions (paper §4)
# ---------------------------------------------------------------------------

MARK = True
UNMARK = False


@dataclass
class Transition:
    src: int
    pred: BitExpr
    mark: bool
    dst: int


@dataclass
class CEA:
    """``A = (Q, Δ, q0, F)``; q0 has no incoming transitions (paper §4)."""

    num_states: int
    transitions: List[Transition]
    q0: int
    finals: Set[int]
    registry: AtomRegistry

    # adjacency: state -> list of transitions
    _adj: Dict[int, List[Transition]] = field(default_factory=dict)

    def __post_init__(self):
        self._adj = {}
        for t in self.transitions:
            self._adj.setdefault(t.src, []).append(t)

    def out(self, state: int) -> List[Transition]:
        return self._adj.get(state, [])


def compile_cel(phi: C.CEL, registry: Optional[AtomRegistry] = None) -> CEA:
    """CEL → CEA (Theorem 1); linear size in ``|φ|``."""
    registry = registry or AtomRegistry()
    b = _Builder(registry)
    v = _compile(phi, b)

    # Single fresh initial state q0 with copies of all initial out-transitions
    # (Appendix A); q0 has no incoming transitions.
    q0 = b.fresh()
    transitions: List[Transition] = []
    for t in v.transitions:
        transitions.append(Transition(t.src, t.pred, bool(t.label), t.dst))
        if t.src in v.initial:
            transitions.append(Transition(q0, t.pred, bool(t.label), t.dst))
    finals = set(v.finals)
    if v.initial & v.finals:
        # ε-accepting formulas cannot arise from this grammar (every formula
        # consumes ≥ 1 event), but guard anyway.
        finals.add(q0)
    return CEA(b.count, transitions, q0, finals, registry)


# ---------------------------------------------------------------------------
# On-the-fly I/O-determinization (paper §4 end + §5.4)
# ---------------------------------------------------------------------------

DetState = int  # interned id of a frozenset of CEA states


class DetCEA:
    """I/O-deterministic view of a CEA via cached subset construction.

    For det state ``P`` and bit-vector ``v``::

        q• = {q | ∃p∈P, (p ─pred/•→ q) ∈ Δ, v ⊨ pred}
        q◦ = {q | ∃p∈P, (p ─pred/◦→ q) ∈ Δ, v ⊨ pred}

    Both successors are themselves det states; the pair is memoized under
    ``(P, v)``.  An event may trigger both a marking and a non-marking
    transition — but never two of the same action — which is exactly the
    I/O-determinism condition.
    """

    def __init__(self, cea: CEA):
        self.cea = cea
        self._interned: Dict[FrozenSet[int], int] = {}
        self._sets: List[FrozenSet[int]] = []
        self._is_final: List[bool] = []
        self._cache: Dict[Tuple[int, int], Tuple[Optional[int], Optional[int]]] = {}
        self.initial = self._intern(frozenset({cea.q0}))

    # -- interning ----------------------------------------------------------
    def _intern(self, states: FrozenSet[int]) -> int:
        sid = self._interned.get(states)
        if sid is None:
            sid = len(self._sets)
            self._interned[states] = sid
            self._sets.append(states)
            self._is_final.append(bool(states & self.cea.finals))
        return sid

    def is_final(self, det_state: int) -> bool:
        return self._is_final[det_state]

    def states_of(self, det_state: int) -> FrozenSet[int]:
        return self._sets[det_state]

    @property
    def num_det_states(self) -> int:
        return len(self._sets)

    # -- the Δ(p, t, m) oracle used by Algorithm 1 ---------------------------
    def step(self, det_state: int, bitvec: int
             ) -> Tuple[Optional[int], Optional[int]]:
        """Returns ``(Δ(p, v, •), Δ(p, v, ◦))`` — ``None`` encodes the dead state."""
        key = (det_state, bitvec)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        marked: Set[int] = set()
        unmarked: Set[int] = set()
        for p in self._sets[det_state]:
            for t in self.cea.out(p):
                if t.pred.evaluate(bitvec):
                    (marked if t.mark else unmarked).add(t.dst)
        q_mark = self._intern(frozenset(marked)) if marked else None
        q_unmark = self._intern(frozenset(unmarked)) if unmarked else None
        result = (q_mark, q_unmark)
        self._cache[key] = result
        return result
