"""Top-level CEQL query execution: parse → compile → evaluate.

This is the public API of the host (reference) engine::

    q = compile_query("SELECT * FROM S WHERE A as x ; B as y WITHIN 10")
    for pos, match in q.run(stream):
        ...
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

from . import ceql
from .cea import CEA, compile_cel
from .engine import Engine, WindowSpec
from .events import ComplexEvent, Event
from .partition import PartitionedEngine
from .predicates import AtomRegistry
from .selection import apply_strategy


@dataclass(frozen=True)
class DeviceSemantics:
    """What the device engines must compile for a query's semantics.

    * ``construction`` — the strategy-aware determinization to build
      (``compile_symbolic(cea, strategy=construction)``): one of
      ALL / STRICT / MAX / NXT.  LAST shares MAX tables.
    * ``latest``  — reduce per-slot counts to the latest live seed slot
      (LAST's second half; slots ↔ seed positions inside the window).
    * ``consume`` — CONSUME BY ANY: clear the query's ring states and arena
      cells after any position that emits (host emit-then-clear order).
    """

    construction: str
    latest: bool
    consume: bool


def resolve_semantics(query: ceql.Query) -> DeviceSemantics:
    """Resolve a query's strategy + CONSUME clause for the device path.

    Raises ``ValueError`` for semantics no device engine can honor —
    mirroring ``kernels.window.resolve_window``'s contradiction errors, so
    an unsupported query can never silently run under ANY semantics.
    """
    strat = query.strategy
    construction = {"ALL": "ALL", "ANY": "ALL", "STRICT": "STRICT",
                    "MAX": "MAX", "LAST": "MAX",
                    "NXT": "NXT", "NEXT": "NXT"}.get(strat)
    if construction is None:
        raise ValueError(
            f"device engines do not implement selection strategy {strat!r}")
    consume = bool(query.consume_on_match)
    if consume and strat == "STRICT":
        # Host CONSUME BY ANY triggers on the *unfiltered* (ANY) match set —
        # the Executor applies the strategy after the engine has already
        # consumed.  MAX/LAST/NXT-filtered sets are non-empty exactly when
        # the ANY set is, so their compiled triggers coincide; STRICT's does
        # not (a position can have ANY-matches but no contiguous one), so
        # strict tables cannot reproduce the host's consumption points.
        raise ValueError(
            "device engines cannot honor CONSUME BY ANY under STRICT: "
            "the consumption trigger (any match) is not observable from "
            "strict-compiled tables; use the host engine for this query")
    return DeviceSemantics(construction=construction,
                           latest=(strat == "LAST"),
                           consume=consume)


@dataclass
class CompiledQuery:
    query: ceql.Query
    cea: CEA

    @property
    def semantics(self) -> DeviceSemantics:
        return resolve_semantics(self.query)

    def make_executor(self, max_enumerate: Optional[int] = None) -> "Executor":
        return Executor(self, max_enumerate=max_enumerate)

    def run(self, stream: Iterable[Event],
            max_enumerate: Optional[int] = None
            ) -> Iterator[Tuple[int, ComplexEvent]]:
        return self.make_executor(max_enumerate).run(stream)


class Executor:
    """Drives (possibly partitioned) engines and applies the selection strategy."""

    def __init__(self, compiled: CompiledQuery,
                 max_enumerate: Optional[int] = None):
        self.compiled = compiled
        q = compiled.query

        def make_engine() -> Engine:
            return Engine(compiled.cea, window=q.window,
                          consume_on_match=q.consume_on_match,
                          max_enumerate=max_enumerate)

        if q.partition_by:
            self.engine: object = PartitionedEngine(make_engine, q.partition_by)
        else:
            self.engine = make_engine()
        self.strategy = q.strategy
        self.j = -1

    def process(self, t: Event) -> List[ComplexEvent]:
        self.j += 1
        matches = self.engine.process(t)
        return apply_strategy(self.strategy, matches)

    def run(self, stream: Iterable[Event]) -> Iterator[Tuple[int, ComplexEvent]]:
        for t in stream:
            for ce in self.process(t):
                yield self.j, ce

    @property
    def stats(self):
        if isinstance(self.engine, PartitionedEngine):
            return [e.stats for e in self.engine.partitions.values()]
        return self.engine.stats


def compile_query(text: str, registry: Optional[AtomRegistry] = None
                  ) -> CompiledQuery:
    q = ceql.parse(text)
    cea = compile_cel(q.formula(), registry)
    return CompiledQuery(q, cea)
