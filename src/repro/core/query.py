"""Top-level CEQL query execution: parse → compile → evaluate.

This is the public API of the host (reference) engine::

    q = compile_query("SELECT * FROM S WHERE A as x ; B as y WITHIN 10")
    for pos, match in q.run(stream):
        ...
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, Iterator, List, Optional, Tuple

from . import ceql
from .cea import CEA, compile_cel
from .engine import Engine, WindowSpec
from .events import ComplexEvent, Event
from .partition import PartitionedEngine
from .predicates import AtomRegistry
from .selection import apply_strategy


@dataclass
class CompiledQuery:
    query: ceql.Query
    cea: CEA

    def make_executor(self, max_enumerate: Optional[int] = None) -> "Executor":
        return Executor(self, max_enumerate=max_enumerate)

    def run(self, stream: Iterable[Event],
            max_enumerate: Optional[int] = None
            ) -> Iterator[Tuple[int, ComplexEvent]]:
        return self.make_executor(max_enumerate).run(stream)


class Executor:
    """Drives (possibly partitioned) engines and applies the selection strategy."""

    def __init__(self, compiled: CompiledQuery,
                 max_enumerate: Optional[int] = None):
        self.compiled = compiled
        q = compiled.query

        def make_engine() -> Engine:
            return Engine(compiled.cea, window=q.window,
                          consume_on_match=q.consume_on_match,
                          max_enumerate=max_enumerate)

        if q.partition_by:
            self.engine: object = PartitionedEngine(make_engine, q.partition_by)
        else:
            self.engine = make_engine()
        self.strategy = q.strategy
        self.j = -1

    def process(self, t: Event) -> List[ComplexEvent]:
        self.j += 1
        matches = self.engine.process(t)
        return apply_strategy(self.strategy, matches)

    def run(self, stream: Iterable[Event]) -> Iterator[Tuple[int, ComplexEvent]]:
        for t in stream:
            for ce in self.process(t):
                yield self.j, ce

    @property
    def stats(self):
        if isinstance(self.engine, PartitionedEngine):
            return [e.stats for e in self.engine.partitions.values()]
        return self.engine.stats


def compile_query(text: str, registry: Optional[AtomRegistry] = None
                  ) -> CompiledQuery:
    q = ceql.parse(text)
    cea = compile_cel(q.formula(), registry)
    return CompiledQuery(q, cea)
