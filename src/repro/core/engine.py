"""CORE's evaluation algorithm (paper §5.3, Algorithm 1).

Incrementally maintains (1) a tECS representing all open complex events and
(2) the set of active det-CEA states, as an insertion-ordered hash table
``T: det-state → union-list``.  Per event the update cost is
``O(|Q|·|Δ|)`` — constant in data complexity, independent of stream length,
window size, and number of partial matches.  At every position ``j`` the set
``⟦A⟧ε_j(S)`` is enumerated from the tECS with output-linear delay
(Algorithm 2 in :mod:`repro.core.tecs`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

from .cea import CEA, DetCEA
from .events import ComplexEvent, Event
from .tecs import (TECS, Node, UnionList, enumerate_node, new_ulist,
                   ulist_insert, ulist_max, ulist_merge)


@dataclass
class WindowSpec:
    """``WITHIN`` clause: count-based (events) or time-based (timestamps)."""

    kind: str = "none"          # 'none' | 'events' | 'time'
    size: float = 0.0
    time_attr: Optional[str] = None  # read timestamps from this attribute

    @staticmethod
    def events(n: int) -> "WindowSpec":
        return WindowSpec("events", float(n))

    @staticmethod
    def time(seconds: float, attr: Optional[str] = None) -> "WindowSpec":
        return WindowSpec("time", seconds, attr)


@dataclass
class EngineStats:
    events: int = 0
    matches: int = 0
    nodes: int = 0
    active_states: int = 0
    det_states: int = 0


class Engine:
    """Algorithm 1 over an I/O-determinized CEA."""

    def __init__(self, cea: CEA, window: WindowSpec = WindowSpec(),
                 consume_on_match: bool = False, max_enumerate: Optional[int] = None,
                 gc_every: int = 512):
        self.det = DetCEA(cea)
        self.registry = cea.registry
        self.window = window
        self.consume_on_match = consume_on_match
        self.max_enumerate = max_enumerate
        self.tecs = TECS()
        # T : det-state -> union-list, iterated in (first-)insertion order.
        # Python dicts preserve first-insertion order under value updates,
        # matching the paper's ordered-keys(T) exactly.
        self.T: Dict[int, UnionList] = {}
        self.j = -1
        self._timestamps: List[float] = []  # position -> timestamp
        self._gc_every = gc_every
        self.stats = EngineStats()

    # ------------------------------------------------------------------
    # window helpers
    # ------------------------------------------------------------------
    def _threshold_start(self, j: int) -> int:
        """Earliest admissible start *position* for outputs closing at ``j``."""
        w = self.window
        if w.kind == "none":
            return 0
        if w.kind == "events":
            return max(0, j - int(w.size))
        # time-based: binary search the earliest position whose timestamp is
        # within [ts(j) - size, ts(j)]  (stream order = time order).
        lo, hi = 0, j
        bound = self._timestamps[j] - w.size
        while lo < hi:
            mid = (lo + hi) // 2
            if self._timestamps[mid] < bound:
                lo = mid + 1
            else:
                hi = mid
        return lo

    # ------------------------------------------------------------------
    # Algorithm 1
    # ------------------------------------------------------------------
    def process(self, t: Event) -> List[ComplexEvent]:
        """Feed one event; return the complex events closing at this position."""
        self.j += 1
        j = self.j
        t.position = j
        if self.window.kind == "time":
            ts = float(t.get(self.window.time_attr)) if self.window.time_attr \
                else (t.timestamp if t.timestamp is not None else float(j))
            self._timestamps.append(ts)
        bitvec = self.registry.bitvector(t)

        Tp: Dict[int, UnionList] = {}

        def add(q: int, n: Node, ul: UnionList) -> None:
            if q in Tp:
                ulist_insert(self.tecs, Tp[q], n)
            else:
                Tp[q] = ul

        def exec_trans(p: int, ul: UnionList) -> None:
            n = ulist_merge(self.tecs, ul)
            q_mark, q_unmark = self.det.step(p, bitvec)
            if q_mark is not None:
                n2 = self.tecs.extend(n, j)
                add(q_mark, n2, new_ulist(n2))
            if q_unmark is not None:
                # Algorithm 1 line 28: pass the ORIGINAL list, not a fresh
                # singleton of the merged node — this keeps union-list heads
                # non-union, so merge() always returns safe (odepth ≤ 1)
                # nodes.  Safe to hand over: I/O-determinism gives each list
                # at most one ◦-successor, and T is discarded after the swap.
                add(q_unmark, n, ul)

        # lines 7–8: a new run may start at the current position
        exec_trans(self.det.initial, new_ulist(self.tecs.new_bottom(j)))
        # lines 9–10: iterate active states in first-insertion order, which
        # provably visits union-lists in decreasing max-start order.
        for p in self.T:
            exec_trans(p, self.T[p])
        self.T = Tp

        out = self._output(j)
        self.stats.events += 1
        self.stats.matches += len(out)
        self.stats.nodes = self.tecs.nodes_created
        self.stats.active_states = len(self.T)
        self.stats.det_states = self.det.num_det_states

        if out and self.consume_on_match:
            # experiments' consumption policy: forget all partial matches
            self.T = {}
        if self._gc_every and j % self._gc_every == self._gc_every - 1:
            self._evict(j)
        return out

    def _output(self, j: int) -> List[ComplexEvent]:
        results: List[ComplexEvent] = []
        threshold = self._threshold_start(j)
        cap = self.max_enumerate
        for p in self.T:
            if self.det.is_final(p):
                n = ulist_merge(self.tecs, self.T[p])
                for ce in enumerate_node(n, j, threshold):
                    results.append(ce)
                    if cap is not None and len(results) >= cap:
                        return results
        return results

    def _evict(self, j: int) -> None:
        """Window eviction (design deviation D3): drop union-list entries whose
        max-start can never satisfy the window again.  Replaces the paper's
        Java weak-reference scheme; amortized constant time."""
        if self.window.kind == "none":
            return
        threshold = self._threshold_start(j)
        dead: List[int] = []
        for q, ul in self.T.items():
            kept = [n for n in ul if n.max_start >= threshold]
            # max(n0) ≥ max(ni) for all i, so kept is empty or still headed by
            # the original non-union n0 — union-list invariants are preserved.
            if not kept:
                dead.append(q)
            elif len(kept) != len(ul):
                self.T[q] = kept
        for q in dead:
            del self.T[q]

    # ------------------------------------------------------------------
    def run(self, stream: Iterable[Event]) -> Iterator[Tuple[int, ComplexEvent]]:
        """Convenience: drive the engine over a stream, yielding (pos, match)."""
        for t in stream:
            for ce in self.process(t):
                yield self.j, ce
