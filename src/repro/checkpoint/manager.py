"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step::

    <dir>/step_<k>.tmp/          # written first
        manifest.json            # tree structure, shapes, dtypes, mesh shape
        arr_<i>.npy              # one file per leaf (host-gathered)
    <dir>/step_<k>/              # atomic rename on completion

* **atomic** — a crashed writer never leaves a readable-but-corrupt step;
  restore picks the newest complete directory.
* **async** — `save(..., blocking=False)` snapshots to host memory and
  writes on a background thread; training continues.
* **elastic** — the manifest stores logical shapes only, so a checkpoint
  written on one mesh restores onto any other mesh (`restore_resharded`
  re-applies the current sharding rules) — elastic scaling across restarts.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..jaxcompat import tree_flatten_with_path


def _flatten_with_paths(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        # serializes publish (rename) + GC: without it a blocking save can
        # overlap an in-flight async write and GC against a half-published
        # directory listing, deleting steps that should have been retained
        self._io_lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             extra: Optional[Dict] = None) -> None:
        # snapshot to host memory first (cheap; device → host copy)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        # never overlap writes: a blocking save issued while an async write
        # is still in flight must drain it first (write order = save order,
        # so GC's newest-K decision matches the caller's step order)
        self.wait()
        if blocking:
            self._write(step, host_tree, extra)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra),
                daemon=True)
            self._thread.start()

    def wait(self) -> None:
        t = self._thread
        if t is not None:
            t.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: Optional[Dict]) -> None:
        tmp = os.path.join(self.directory, f"step_{step}.tmp")
        final = os.path.join(self.directory, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _flatten_with_paths(host_tree)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for i, (key, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            manifest["leaves"].append(
                {"key": key, "file": f"arr_{i}.npy",
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with self._io_lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)   # atomic publish
            self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                path = os.path.join(self.directory, name)
                if os.path.exists(os.path.join(path, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_arrays(self, step: Optional[int] = None
                    ) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Template-free restore: ``(key → array, extra)`` of one step.

        The manifest records each leaf's key/shape/dtype, so a caller that
        knows its own layout (e.g. the streaming-engine recovery layer,
        which may *rescale* lanes on restore) can read a checkpoint without
        first building a shape-identical template tree.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {leaf["key"]: np.load(os.path.join(path, leaf["file"]))
                  for leaf in manifest["leaves"]}
        return arrays, manifest["extra"]

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict]:
        """Restore into the structure of `template` (shapes must match)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = {}
        for leaf in manifest["leaves"]:
            arrays[leaf["key"]] = np.load(os.path.join(path, leaf["file"]))
        leaves, treedef = _flatten_with_paths(template)
        restored = []
        for key, tmpl in leaves:
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = arrays[key]
            want = tuple(np.shape(tmpl))
            if tuple(arr.shape) != want:
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {want}")
            restored.append(arr.astype(np.asarray(tmpl).dtype
                                       if hasattr(tmpl, "dtype") else arr.dtype))
        tree = jax.tree.unflatten(treedef, restored)
        return tree, manifest["extra"]


def restore_resharded(manager: CheckpointManager, template: Any,
                      shardings: Any, step: Optional[int] = None
                      ) -> Tuple[Any, Dict]:
    """Restore a checkpoint and place it under new shardings (elastic
    restart onto a different mesh: the checkpoint stores logical arrays,
    `jax.device_put` re-shards them under the new topology)."""
    tree, extra = manager.restore(template, step)
    placed = jax.tree.map(
        lambda arr, s: jax.device_put(arr, s), tree, shardings)
    return placed, extra
