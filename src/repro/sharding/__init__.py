from .axis_rules import (DECODE_RULES, LONG_DECODE_RULES, TRAIN_RULES,
                         AxisRules, current_rules, logical_spec, set_rules,
                         with_logical_constraint)

__all__ = ["AxisRules", "current_rules", "logical_spec", "set_rules",
           "with_logical_constraint", "TRAIN_RULES", "DECODE_RULES",
           "LONG_DECODE_RULES"]
