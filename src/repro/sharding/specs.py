"""Build parameter/state PartitionSpec trees from logical-axes trees."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .axis_rules import AxisRules, divisible_spec


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def spec_tree(axes_tree: Any, rules: AxisRules) -> Any:
    """axes tree (tuples of logical names) → PartitionSpec tree."""
    if _is_axes_leaf(axes_tree):
        return rules.spec(axes_tree)
    if isinstance(axes_tree, dict):
        return {k: spec_tree(v, rules) for k, v in axes_tree.items()}
    if isinstance(axes_tree, (list, tuple)):
        return type(axes_tree)(spec_tree(v, rules) for v in axes_tree)
    raise TypeError(f"bad axes node {axes_tree!r}")


def sharding_tree(params: Any, axes_tree: Any, rules: AxisRules, mesh: Mesh
                  ) -> Any:
    """Matched (params, axes) trees → NamedSharding tree with divisibility
    checks against concrete shapes (drops non-dividing axes per dim)."""
    sizes = {a: int(s) for a, s in zip(mesh.axis_names,
                                       np.shape(mesh.devices))}

    def go(p, a):
        if _is_axes_leaf(a):
            spec = rules.spec(a)
            shape = tuple(getattr(p, 'shape', np.shape(p)))
            spec = divisible_spec(spec, shape, sizes)
            return NamedSharding(mesh, spec)
        if isinstance(a, dict):
            return {k: go(p[k], a[k]) for k in a}
        if isinstance(a, (list, tuple)):
            return type(a)(go(pp, aa) for pp, aa in zip(p, a))
        raise TypeError(f"bad axes node {a!r}")

    return go(params, axes_tree)
