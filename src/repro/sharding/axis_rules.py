"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names
(``("batch", "seq", "d_model")``); a rules table maps logical names to mesh
axes.  Swapping the table re-shards the whole model — this is how the same
stack serves train (FSDP×TP), prefill (DP×TP) and long-context decode
(SP×TP) without touching model code.

A logical name may map to a single mesh axis, a tuple of mesh axes (the
dimension is sharded over their product), or ``None`` (replicated).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class AxisRules:
    rules: Tuple[Tuple[str, MeshAxes], ...]

    @staticmethod
    def of(**kw: MeshAxes) -> "AxisRules":
        return AxisRules(tuple(kw.items()))

    def lookup(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        seen = []
        out = []
        for name in logical_axes:
            axes = self.lookup(name)
            if axes is None:
                out.append(None)
                continue
            axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
            # a mesh axis may appear at most once in a PartitionSpec
            axes_t = tuple(a for a in axes_t if a not in seen)
            seen.extend(axes_t)
            if not axes_t:
                out.append(None)
            elif len(axes_t) == 1:
                out.append(axes_t[0])
            else:
                out.append(axes_t)
        return P(*out)


# Default rules: FSDP over `data`, TP over `model`, DP over `pod`+`data`,
# Megatron-style sequence parallelism: the residual stream (and logits/CE)
# shard `seq` over `model` between blocks; TP regions gather seq internally.
TRAIN_RULES = AxisRules.of(
    batch=("pod", "data"),
    seq="model",
    d_model=None,
    heads="model",
    kv_heads="model",
    head_dim=None,
    ffn="model",
    experts="model",
    expert_ffn=None,
    vocab="model",
    fsdp="data",          # parameter sharding axis (ZeRO-3 style)
    window=None,
    states=None,
    cache_seq=None,
    conv=None,
)

# Decode/prefill: batch over pod+data, heads/experts over model; params keep
# the fsdp axis too — a 671B checkpoint does not fit 256 chips TP-only.
# cache_seq shards over `model`: with kv_heads < model-axis size the cache
# cannot shard by head, and a model-replicated cache made GSPMD re-gather the
# full 32k KV cache EVERY LAYER (29.3 GB/step wire on granite — §Perf Track
# 3); seq-sharding it cuts decode wire 84× and cache memory 16×.
DECODE_RULES = AxisRules.of(
    batch=("pod", "data"),
    seq=None,
    d_model=None,
    heads="model",
    kv_heads="model",
    head_dim=None,
    ffn="model",
    experts="model",
    expert_ffn=None,
    vocab="model",
    fsdp="data",
    window=None,
    states=None,
    cache_seq="model",
    conv=None,
)

# Long-context decode (batch=1): sequence parallelism — the KV/conv caches and
# attention shard their *sequence* axis over `data`, heads over `model`.
LONG_DECODE_RULES = AxisRules.of(
    batch="pod",
    seq=None,
    d_model=None,
    heads="model",
    kv_heads="model",
    head_dim=None,
    ffn="model",
    experts="model",
    expert_ffn=None,
    vocab="model",
    fsdp="data",
    window=None,
    states=None,
    cache_seq="data",
    conv=None,
)

_local = threading.local()


def current_rules() -> AxisRules:
    return getattr(_local, "rules", TRAIN_RULES)


@contextmanager
def set_rules(rules: AxisRules):
    prev = current_rules()
    _local.rules = rules
    try:
        yield
    finally:
        _local.rules = prev


def logical_spec(logical_axes: Sequence[Optional[str]]) -> P:
    return current_rules().spec(logical_axes)


def divisible_spec(spec: P, shape: Tuple[int, ...], axis_sizes: Dict[str, int]
                   ) -> P:
    """Drop mesh axes that do not divide the corresponding dim size.

    GSPMD requires exact divisibility; e.g. kv_heads=8 cannot shard over a
    model axis of 16, so the constraint silently degrades to replication for
    that dim (MaxText does the same with its `sharding_tolerance`).
    """
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        kept, tot = [], 1
        for a in axes:
            if a not in axis_sizes:   # axis absent from this mesh (e.g. pod)
                continue
            sz = axis_sizes[a]
            if dim % (tot * sz) == 0:
                kept.append(a)
                tot *= sz
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    return P(*out)


def with_logical_constraint(x: jax.Array,
                            logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Annotate activation sharding; no-op outside a `jax.set_mesh` context."""
    from ..jaxcompat import current_mesh
    mesh = current_mesh()
    if mesh is None:
        return x
    try:
        # inside shard_map the axes are Manual: layout is already explicit
        if any(t != jax.sharding.AxisType.Auto for t in mesh.axis_types):
            return x
    except Exception:
        return x
    if len(logical_axes) != x.ndim:
        return x
    spec = logical_spec(logical_axes)
    spec = divisible_spec(spec, x.shape, dict(mesh.shape))
    return jax.lax.with_sharding_constraint(x, spec)
