"""Version-compat shims for the jax APIs this repo uses (jax ≥ 0.4.37).

The repo targets both the 0.4.x LTS line and current jax; a handful of APIs
moved or appeared in between.  Every call site routes through this module so
the version forks live in exactly one place:

* ``jax.tree.flatten_with_path``      — added after 0.4.x; falls back to
  ``jax.tree_util.tree_flatten_with_path`` (same (path, leaf) contract).
* ``jax.shard_map(..., check_vma=)``  — on 0.4.x it is
  ``jax.experimental.shard_map.shard_map(..., check_rep=)``.
* ``jax.make_mesh(..., axis_types=)`` — ``axis_types`` /
  ``jax.sharding.AxisType`` only exist on newer jax; older versions get the
  plain mesh (all axes implicitly Auto).
* ``jax.set_mesh`` / ``jax.sharding.get_abstract_mesh`` — the ambient-mesh
  context is newer-jax-only; older versions no-op (shard_map callers always
  receive the mesh explicitly, so the context is advisory).
"""
from __future__ import annotations

import contextlib
from typing import Any, List, Optional, Tuple

import jax


def tree_flatten_with_path(tree: Any) -> Tuple[List[Tuple[Any, Any]], Any]:
    """``jax.tree.flatten_with_path`` across versions."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across versions (older: jax.experimental).

    Replication checking is disabled either way (``check_vma`` on new jax,
    ``check_rep`` on old) — the callers' out_specs are authoritative.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` across versions (axis_types only where supported)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def use_mesh(mesh):
    """Context manager: ``jax.set_mesh`` where available, else a no-op.

    shard_map receives the mesh explicitly, so on older jax the ambient-mesh
    context is unnecessary — entering it is still harmless either way.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh)


def current_mesh() -> Optional[Any]:
    """The ambient abstract mesh, or None (no context / older jax)."""
    try:
        m = jax.sharding.get_abstract_mesh()
        return None if m.empty else m
    except Exception:
        return None
