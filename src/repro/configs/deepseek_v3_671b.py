"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed, MTP
[arXiv:2412.19437; hf].

MLA: q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
qk_rope_head_dim=64, v_head_dim=128.  First 3 layers dense (d_ff=18432).
Optimizer moments in bf16 — fp32 m/v would not fit 512×16 GB (EXPERIMENTS.md
§Dry-run memory table).
"""
import dataclasses

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,            # qk nope head dim
    d_ff=18432,              # dense layers (first 3)
    vocab_size=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    v_head_dim=128,
    mlp="swiglu",
    moe=MoEConfig(num_experts=256, top_k=8, d_ff=2048,
                  num_shared_experts=1, shared_d_ff=2048,
                  capacity_factor=1.25),
    first_dense_layers=3,
    mtp_depth=1,
    rope_theta=10000.0,
    # bf16 master weights + bf16 moments: fp32 anything would exceed the
    # 16 GB/chip of a 256-chip v5e pod (params alone are 2.7 TB in fp32).
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=256,
        q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16, v_head_dim=32,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=64, num_shared_experts=1,
                      shared_d_ff=64, capacity_factor=4.0),
        first_dense_layers=1, mtp_depth=1, dtype="float32",
        param_dtype="float32", opt_state_dtype="float32", remat=False)
