"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
import dataclasses

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    mlp="swiglu",
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512, capacity_factor=1.25),
    rope_theta=10000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=64, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=64, capacity_factor=4.0),
        dtype="float32", param_dtype="float32", remat=False)
