"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

input_specs() provides precomputed frame embeddings (B, 1500, 512) — the
mel+conv frontend is out of scope per the assignment.  Decode shapes lower
the decoder's serve_step (self-attn KV cache of seq_len + static cross-attn
KV over the 1500 encoder frames).  long_500k skipped: full attention.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    mlp="gelu",
    encoder_layers=6,
    encoder_seq=1500,
    cross_attention=True,
    frontend="audio_stub",
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, encoder_layers=2,
        encoder_seq=30, dtype="float32", param_dtype="float32", remat=False)
