"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1000000.0,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=256, dtype="float32",
        param_dtype="float32", remat=False)
