"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf].  GELU MLP + qkv bias per the
StarCoder2 reference implementation."""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    mlp="gelu",
    rope_theta=100000.0,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=256, dtype="float32",
        param_dtype="float32", remat=False)
