"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
— InternViT frontend STUB + Qwen2-0.5B-style backbone [arXiv:2404.16821; hf].

input_specs() provides precomputed patch embeddings (B, 256, 1024); a linear
projector maps them into the LM and they are prepended to the token sequence.
long_500k skipped: full attention.
"""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1000000.0,
    frontend="vision_stub",
    frontend_seq=256,
    frontend_dim=1024,
    tie_embeddings=True,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=256, frontend_seq=8,
        frontend_dim=64, dtype="float32", param_dtype="float32", remat=False)
