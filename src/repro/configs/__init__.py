"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (the exact published configuration) and
``smoke()`` (a reduced same-family config for CPU tests).  ``SHAPES`` lists
the input-shape cells each arch participates in (long_500k only for
sub-quadratic archs, decode only for archs with a decoder — per the
assignment's skip rules, documented in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from ..models.config import ModelConfig

ARCHS = [
    "zamba2_2p7b",
    "qwen3_32b",
    "starcoder2_15b",
    "qwen2p5_14b",
    "deepseek_coder_33b",
    "deepseek_v3_671b",
    "granite_moe_1b",
    "rwkv6_1p6b",
    "whisper_base",
    "internvl2_1b",
]

# canonical ids from the assignment → module names
ALIASES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen3-32b": "qwen3_32b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2.5-14b": "qwen2p5_14b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "whisper-base": "whisper_base",
    "internvl2-1b": "internvl2_1b",
}

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{ALIASES.get(arch, arch)}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f".{ALIASES.get(arch, arch)}", __package__)
    return mod.smoke()


def shapes_for(cfg: ModelConfig) -> List[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        out.append("long_500k")
    return out


def all_cells() -> List[Tuple[str, str]]:
    """Every (arch, shape) dry-run cell (skips applied)."""
    cells = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in shapes_for(cfg):
            cells.append((a, s))
    return cells
