"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch [arXiv:2401.14196; hf]."""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    mlp="swiglu",
    rope_theta=100000.0,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=256, dtype="float32",
        param_dtype="float32", remat=False)
