"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  The shared transformer block (one parameter set,
GQA + MLP) is invoked every 6th layer — real Zamba2 also concatenates the
original embedding into the shared-block input and applies per-invocation
LoRA deltas; both are simplified away here (DESIGN.md §4).
Sub-quadratic backbone ⇒ runs long_500k.
"""
import dataclasses

from ..models.config import MAMBA2, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    block_kind=MAMBA2,
    ssm=SSMConfig(state_dim=64, num_heads=80, head_dim=64, conv_width=4,
                  chunk=256, expand=2),
    shared_attn_every=6,
    mlp="gelu",
    rope_theta=10000.0,
    supports_long_context=True,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=12, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=0, d_ff=256, vocab_size=256,
        ssm=SSMConfig(state_dim=16, num_heads=4, head_dim=64, conv_width=4,
                      chunk=8, expand=2),
        shared_attn_every=3, dtype="float32", param_dtype="float32",
        remat=False)
