"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
import dataclasses

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    mlp="swiglu",
    rope_theta=1000000.0,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=256, dtype="float32",
        param_dtype="float32", remat=False)
