"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892; unverified].
O(1) state ⇒ runs long_500k."""
import dataclasses

from ..models.config import RWKV6, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # d_model / 64 rwkv heads
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    block_kind=RWKV6,
    supports_long_context=True,
    param_dtype="bfloat16",   # §Perf: halves weight traffic (FSDP gathers + reads)
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=0, d_ff=256, vocab_size=256, dtype="float32",
        param_dtype="float32", remat=False)
